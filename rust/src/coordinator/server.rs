//! TCP JSON-lines front-end for the serving engine.
//!
//! Protocol: one JSON object per line.
//!
//! ```text
//! -> {"id": 1, "prompt": [12, 3, 4], "max_new": 16, "temperature": 0.8}
//! <- {"id": 1, "tokens": [5, 6, ...], "latency_us": 1234}
//! ```
//!
//! Malformed lines get `{"id": 0, "error": "..."}`. One thread per
//! connection; responses are written in completion order.
//!
//! # Example
//!
//! ```no_run
//! use std::sync::Arc;
//! use linear_transformer::attention::AttentionKind;
//! use linear_transformer::config::{ModelConfig, ServeConfig};
//! use linear_transformer::coordinator::engine::NativeEngine;
//! use linear_transformer::coordinator::request::GenerateRequest;
//! use linear_transformer::coordinator::server::{request_over_tcp, Server};
//! use linear_transformer::nn::TransformerLM;
//!
//! let model = TransformerLM::init(&ModelConfig::small_copy(), AttentionKind::Linear, 0);
//! let engine = Arc::new(NativeEngine::spawn(model, ServeConfig::default()).unwrap());
//! let server = Server::start("127.0.0.1:0", engine).unwrap();
//! let resps = request_over_tcp(
//!     &server.addr.to_string(),
//!     &[GenerateRequest { id: 1, prompt: vec![12, 3], max_new: 4, temperature: 0.0, top_k: 0 }],
//! )
//! .unwrap();
//! assert_eq!(resps[0].tokens.len(), 4);
//! server.stop();
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;

use crate::coordinator::engine::EngineHandle;
use crate::coordinator::request::{GenerateRequest, GenerateResponse};
use crate::json::Json;

/// A running TCP server bound to `addr`.
pub struct Server {
    pub addr: std::net::SocketAddr,
    listener_thread: Option<std::thread::JoinHandle<()>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
}

impl Server {
    /// Bind and serve requests against `engine` until stopped.
    pub fn start(bind: &str, engine: Arc<EngineHandle>) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop_l = stop.clone();
        let listener_thread = std::thread::Builder::new()
            .name("lintra-server".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop_l.load(std::sync::atomic::Ordering::Relaxed) {
                    // reap finished connection threads: a long-lived
                    // server must not accumulate one JoinHandle (and its
                    // retained thread resources) per past connection
                    conns.retain(|c| !c.is_finished());
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let engine = engine.clone();
                            let spawned = std::thread::Builder::new()
                                .name("lintra-conn".into())
                                .spawn(move || handle_conn(stream, engine));
                            match spawned {
                                Ok(h) => conns.push(h),
                                Err(e) => {
                                    // OS thread exhaustion: shed this
                                    // connection (the client sees a
                                    // closed socket) instead of killing
                                    // the accept loop — and the server —
                                    // with a panic
                                    eprintln!("[server] dropping connection: {e}");
                                }
                            }
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(Server {
            addr,
            listener_thread: Some(listener_thread),
            stop,
        })
    }

    pub fn stop(mut self) {
        self.stop
            .store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = self.listener_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop
            .store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = self.listener_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, engine: Arc<EngineHandle>) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    // responses flow back over a channel so multiple in-flight requests
    // per connection complete out of order without blocking the reader
    let (resp_tx, resp_rx) = channel::<GenerateResponse>();
    let mut write_half = stream;
    let writer = std::thread::spawn(move || {
        for resp in resp_rx {
            let mut line = resp.to_json().to_string();
            line.push('\n');
            if write_half.write_all(line.as_bytes()).is_err() {
                break;
            }
        }
    });

    let mut in_flight: Vec<std::thread::JoinHandle<()>> = Vec::new();
    for line in reader.lines() {
        // reap completed per-request threads so a connection that
        // streams many requests stays bounded
        in_flight.retain(|h| !h.is_finished());
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .and_then(|j| GenerateRequest::from_json(&j));
        match parsed {
            Ok(req) => {
                let id = req.id;
                let rx = engine.submit(req);
                let tx = resp_tx.clone();
                in_flight.push(std::thread::spawn(move || {
                    // a worker that dies after submit drops the responder;
                    // answer with an error line instead of leaving the
                    // client waiting forever for this id
                    let resp = rx
                        .recv()
                        .unwrap_or_else(|_| crate::coordinator::engine::engine_gone_response(id));
                    let _ = tx.send(resp);
                }));
            }
            Err(e) => {
                let _ = resp_tx.send(GenerateResponse {
                    id: 0,
                    tokens: vec![],
                    latency_us: 0,
                    truncated: false,
                    error: Some(format!("bad request from {peer:?}: {e}")),
                });
            }
        }
    }
    for h in in_flight {
        let _ = h.join();
    }
    drop(resp_tx);
    let _ = writer.join();
}

/// Minimal client for tests/benches and the `lintra client` subcommand.
pub fn request_over_tcp(
    addr: &str,
    reqs: &[GenerateRequest],
) -> anyhow::Result<Vec<GenerateResponse>> {
    let mut stream = TcpStream::connect(addr)?;
    for r in reqs {
        let mut line = r.to_json().to_string();
        line.push('\n');
        stream.write_all(line.as_bytes())?;
    }
    stream.shutdown(std::net::Shutdown::Write)?;
    let reader = BufReader::new(stream);
    let mut out = Vec::new();
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
        out.push(GenerateResponse::from_json(&j)?);
        if out.len() == reqs.len() {
            break;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttentionKind;
    use crate::config::{ModelConfig, ServeConfig};
    use crate::coordinator::engine::NativeEngine;
    use crate::nn::TransformerLM;

    fn tiny_engine() -> Arc<EngineHandle> {
        let cfg = ModelConfig {
            vocab: 11,
            d_model: 32,
            n_heads: 2,
            n_layers: 1,
            max_len: 64,
            d_ff: 64,
            chunk: 16,
            causal: true,
            lsh_rounds: 1,
            lsh_buckets: 8,
            lsh_chunk: 8,
        };
        let model = TransformerLM::init(&cfg, AttentionKind::Linear, 0);
        Arc::new(NativeEngine::spawn(model, ServeConfig::default()).unwrap())
    }

    #[test]
    fn end_to_end_over_tcp() {
        let engine = tiny_engine();
        let server = Server::start("127.0.0.1:0", engine).unwrap();
        let addr = server.addr.to_string();
        let reqs: Vec<_> = (1..=3u64)
            .map(|id| GenerateRequest {
                id,
                prompt: vec![1, 2],
                max_new: 4,
                temperature: 0.0,
                top_k: 0,
            })
            .collect();
        let resps = request_over_tcp(&addr, &reqs).unwrap();
        assert_eq!(resps.len(), 3);
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3]);
        for r in &resps {
            assert_eq!(r.tokens.len(), 4);
            assert!(r.error.is_none());
        }
        server.stop();
    }

    #[test]
    fn many_sequential_connections_stay_healthy() {
        // exercises the reaping path: each connection's thread finishes
        // and is retained-out before the next accept; the server keeps
        // answering correctly throughout
        let engine = tiny_engine();
        let server = Server::start("127.0.0.1:0", engine).unwrap();
        let addr = server.addr.to_string();
        for i in 0..20u64 {
            let resps = request_over_tcp(
                &addr,
                &[GenerateRequest {
                    id: i,
                    prompt: vec![1, 2],
                    max_new: 2,
                    temperature: 0.0,
                    top_k: 0,
                }],
            )
            .unwrap();
            assert_eq!(resps.len(), 1);
            assert_eq!(resps[0].id, i);
            assert!(resps[0].error.is_none(), "{:?}", resps[0].error);
        }
        server.stop();
    }

    #[test]
    fn malformed_line_gets_error_response() {
        let engine = tiny_engine();
        let server = Server::start("127.0.0.1:0", engine).unwrap();
        let addr = server.addr.to_string();
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(b"this is not json\n").unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        let resp = GenerateResponse::from_json(&j).unwrap();
        assert!(resp.error.is_some());
        server.stop();
    }
}
