//! Training driver: runs `*_train` AOT artifacts step-wise through PJRT.
//!
//! The artifact's calling convention (fixed by aot.py and asserted against
//! the manifest):
//!   inputs  = params..., opt_m..., opt_v..., opt_step, lr, batch fields...
//!   outputs = loss, params..., opt_m..., opt_v..., opt_step
//! The trainer carries the parameter/optimizer state as host `Value`s
//! between steps, applies the paper's LR schedule, logs a loss CSV
//! (Figure 2 / Figure 5 curves), and checkpoints LTW1 bundles the native
//! models can load.

use std::io::Write;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context};

use crate::config::TrainConfig;
use crate::runtime::{LoadedArtifact, Runtime, Value};

use crate::weights::{NamedTensor, WeightBundle};

/// One step's outcome.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub step: usize,
    pub loss: f32,
    pub step_time: std::time::Duration,
}

/// The training driver for one `<task>_<variant>` model.
pub struct Trainer {
    pub model_key: String,
    artifact: Rc<LoadedArtifact>,
    param_names: Vec<String>,
    params: Vec<Value>,
    opt_m: Vec<Value>,
    opt_v: Vec<Value>,
    opt_step: Value,
    /// number of fixed (non-batch) inputs = 3P + 2
    n_state_inputs: usize,
    pub history: Vec<StepStats>,
}

impl Trainer {
    /// Load the train artifact + initial weights for `<task>_<variant>`.
    pub fn new(rt: &mut Runtime, task: &str, variant: &str) -> anyhow::Result<Trainer> {
        let model_key = format!("{task}_{variant}");
        let artifact = rt.load(&format!("{model_key}_train"))?;
        let spec = rt
            .bundle
            .model(&model_key)
            .with_context(|| format!("model {model_key} not in manifest"))?;
        let param_names = spec.params.clone();
        let weights = rt.load_weights(&model_key)?;
        let params: Vec<Value> = param_names
            .iter()
            .map(|n| Value::from_tensor(weights.req(n)))
            .collect();
        // sanity: artifact input layout matches the convention
        let p = param_names.len();
        let expect = |i: usize, prefix: &str| -> anyhow::Result<()> {
            let name = &artifact.spec.inputs[i].name;
            if !name.starts_with(prefix) {
                bail!("{model_key}_train input {i} is {name:?}, expected {prefix}*");
            }
            Ok(())
        };
        expect(0, "param:")?;
        expect(p, "opt_m:")?;
        expect(2 * p, "opt_v:")?;
        if artifact.spec.inputs[3 * p].name != "opt_step" {
            bail!("train artifact layout mismatch at opt_step");
        }
        if artifact.spec.inputs[3 * p + 1].name != "lr" {
            bail!("train artifact layout mismatch at lr");
        }
        let opt_m: Vec<Value> = params
            .iter()
            .map(|v| Value::F32(v.shape().to_vec(), vec![0.0; v.numel()]))
            .collect();
        let opt_v = opt_m.clone();
        Ok(Trainer {
            model_key,
            artifact,
            param_names,
            params,
            opt_m,
            opt_v,
            opt_step: Value::scalar_f32(0.0),
            n_state_inputs: 3 * p + 2,
            history: Vec::new(),
        })
    }

    /// Shapes of the batch inputs the artifact expects (after lr).
    pub fn batch_specs(&self) -> &[crate::runtime::TensorSpec] {
        &self.artifact.spec.inputs[self.n_state_inputs..]
    }

    /// Run one optimizer step with the given batch values.
    pub fn step(&mut self, lr: f32, batch: Vec<Value>) -> anyhow::Result<StepStats> {
        let p = self.param_names.len();
        let mut inputs =
            Vec::with_capacity(self.n_state_inputs + batch.len());
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.opt_m.iter().cloned());
        inputs.extend(self.opt_v.iter().cloned());
        inputs.push(self.opt_step.clone());
        inputs.push(Value::scalar_f32(lr));
        inputs.extend(batch);
        let t0 = Instant::now();
        let mut out = self.artifact.run(&inputs)?;
        let step_time = t0.elapsed();
        let loss = out[0].scalar()?;
        // outputs: loss, params, m, v, step
        let mut it = out.drain(1..);
        self.params = (&mut it).take(p).collect();
        self.opt_m = (&mut it).take(p).collect();
        self.opt_v = (&mut it).take(p).collect();
        self.opt_step = it.next().context("missing opt_step output")?;
        let stats = StepStats {
            step: self.history.len() + 1,
            loss,
            step_time,
        };
        self.history.push(stats);
        Ok(stats)
    }

    /// Current parameters as an LTW1 bundle (for checkpointing / native eval).
    pub fn weights(&self) -> anyhow::Result<WeightBundle> {
        let tensors = self
            .param_names
            .iter()
            .zip(&self.params)
            .map(|(n, v)| {
                Ok(NamedTensor {
                    name: n.clone(),
                    tensor: v.clone().into_tensor()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(WeightBundle::new(tensors))
    }

    pub fn save_checkpoint(&self, path: &str) -> anyhow::Result<()> {
        self.weights()?.save(path)
    }

    /// Mean step time over the recorded history.
    pub fn mean_step_time(&self) -> std::time::Duration {
        if self.history.is_empty() {
            return std::time::Duration::ZERO;
        }
        self.history.iter().map(|s| s.step_time).sum::<std::time::Duration>()
            / self.history.len() as u32
    }
}

/// Drive a full training run with the paper's LR schedule, logging CSV.
pub fn train_loop(
    trainer: &mut Trainer,
    cfg: &TrainConfig,
    mut next_batch: impl FnMut(usize) -> Vec<Value>,
) -> anyhow::Result<()> {
    let mut csv: Option<std::fs::File> = match &cfg.out_csv {
        Some(path) => {
            if let Some(dir) = std::path::Path::new(path).parent() {
                std::fs::create_dir_all(dir)?;
            }
            let mut f = std::fs::File::create(path)?;
            writeln!(f, "step,loss,elapsed_s")?;
            Some(f)
        }
        None => None,
    };
    let t0 = Instant::now();
    for step in 0..cfg.steps {
        let lr = cfg.lr_at(step);
        let stats = trainer.step(lr, next_batch(step))?;
        if let Some(f) = csv.as_mut() {
            writeln!(
                f,
                "{},{:.6},{:.3}",
                stats.step,
                stats.loss,
                t0.elapsed().as_secs_f64()
            )?;
        }
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            eprintln!(
                "[train {}] step {:>5} loss {:.4} lr {:.1e} ({:.0} ms/step)",
                trainer.model_key,
                stats.step,
                stats.loss,
                lr,
                stats.step_time.as_secs_f64() * 1e3,
            );
        }
    }
    if let Some(path) = &cfg.checkpoint {
        trainer.save_checkpoint(path)?;
        eprintln!("[train {}] checkpoint -> {path}", trainer.model_key);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// batch builders per task (data generators -> artifact Values)
// ---------------------------------------------------------------------------

/// Copy-task batches.
pub fn copy_batch_fn(
    seq_len: usize,
    batch: usize,
    seed: u64,
) -> impl FnMut(usize) -> Vec<Value> {
    let mut gen = crate::data::CopyTask::new(seq_len, seed);
    move |_step| {
        let b = gen.batch(batch);
        vec![
            Value::I32(vec![batch, seq_len], b.inputs.iter().map(|&t| t as i32).collect()),
            Value::I32(vec![batch, seq_len], b.targets.iter().map(|&t| t as i32).collect()),
            Value::F32(vec![batch, seq_len], b.mask),
        ]
    }
}

/// Image (mnist/cifar) LM batches — mask is all-ones.
pub fn image_batch_fn(
    kind: crate::data::ImageKind,
    batch: usize,
    seed: u64,
) -> impl FnMut(usize) -> Vec<Value> {
    let mut gen = crate::data::ImageDataset::new(kind, seed);
    let n = kind.seq_len();
    move |_step| {
        let (inputs, targets) = gen.lm_batch(batch);
        vec![
            Value::I32(vec![batch, n], inputs.iter().map(|&t| t as i32).collect()),
            Value::I32(vec![batch, n], targets.iter().map(|&t| t as i32).collect()),
            Value::F32(vec![batch, n], vec![1.0; batch * n]),
        ]
    }
}

/// Speech CTC batches.
pub fn speech_batch_fn(
    max_frames: usize,
    batch: usize,
    max_labels: usize,
    seed: u64,
) -> impl FnMut(usize) -> Vec<Value> {
    let mut gen = crate::data::SpeechDataset::new(max_frames, seed);
    move |_step| {
        let (feats, frame_len, labels, label_len) = gen.batch(batch, max_labels);
        vec![
            Value::F32(vec![batch, max_frames, crate::data::speech::N_MELS], feats),
            Value::I32(vec![batch], frame_len),
            Value::I32(vec![batch, max_labels], labels),
            Value::I32(vec![batch], label_len),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_fns_produce_declared_shapes() {
        let mut f = copy_batch_fn(128, 4, 0);
        let vals = f(0);
        assert_eq!(vals.len(), 3);
        assert_eq!(vals[0].shape(), &[4, 128]);
        assert_eq!(vals[2].shape(), &[4, 128]);

        let mut g = image_batch_fn(crate::data::ImageKind::MnistLike, 2, 0);
        let vals = g(0);
        assert_eq!(vals[0].shape(), &[2, 784]);

        let mut s = speech_batch_fn(64, 3, 16, 0);
        let vals = s(0);
        assert_eq!(vals[0].shape(), &[3, 64, 40]);
        assert_eq!(vals[1].shape(), &[3]);
        assert_eq!(vals[2].shape(), &[3, 16]);
    }

    #[test]
    fn batches_vary_across_steps() {
        let mut f = copy_batch_fn(64, 2, 1);
        let a = f(0);
        let b = f(1);
        assert_ne!(a[0].as_i32().unwrap(), b[0].as_i32().unwrap());
    }
}
