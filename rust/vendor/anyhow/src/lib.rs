//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! provides the subset of anyhow's API the workspace uses: [`Error`] (a
//! context-chaining string error), [`Result`], the [`Context`] extension
//! trait for `Result` and `Option`, and the `anyhow!` / `bail!` macros.
//! Error text formatting matches anyhow's conventions: `{}` prints the
//! outermost message, `{:#}` the full `outer: cause: cause` chain, and
//! `{:?}` the message plus a `Caused by:` list.

use std::fmt;

/// A string error with an ordered chain of underlying causes.
pub struct Error {
    msg: String,
    causes: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            causes: Vec::new(),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        let mut causes = Vec::with_capacity(self.causes.len() + 1);
        causes.push(self.msg);
        causes.extend(self.causes);
        Error {
            msg: c.to_string(),
            causes,
        }
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.causes.iter().map(|s| s.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for c in &self.causes {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in &self.causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let msg = e.to_string();
        let mut causes = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            causes.push(s.to_string());
            src = s.source();
        }
        Error { msg, causes }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading weights")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: disk on fire");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        let e = anyhow!("bad {}", 7);
        assert_eq!(format!("{e}"), "bad 7");
        fn bails() -> Result<()> {
            bail!("nope");
        }
        assert!(bails().is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
