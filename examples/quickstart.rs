//! Quickstart: the three-layer stack in one file.
//!
//! 1. loads the AOT weights + decode artifact (built by `make artifacts`),
//! 2. generates a copy-task continuation through the **PJRT** decode step
//!    (the jax/Pallas-lowered RNN formulation, eqs 16-20),
//! 3. generates the same continuation through the **native rust** RNN
//!    session and checks they agree,
//! 4. prints the decode-state size to show it is constant in sequence length.
//!
//! Without the AOT artifacts (e.g. in CI) it falls back to a native-only
//! demo on random-init weights, honoring LINTRA_WEIGHT_DTYPE — so the
//! example doubles as a smoke test for the low-precision weight paths.
//!
//! Run: `cargo run --release --example quickstart`

use linear_transformer::attention::AttentionKind;
use linear_transformer::nn::TransformerLM;
use linear_transformer::runtime::{Runtime, Value};

/// No artifacts available: exercise the native decode stack end-to-end
/// (model init, weight cast per the ambient env, session decode) and
/// print the same punchlines the full path would.
fn native_only_demo() -> anyhow::Result<()> {
    let cfg = linear_transformer::config::ModelConfig::small_copy();
    // init applies LINTRA_WEIGHT_DTYPE (config::resolve_weight_dtype)
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 42);
    println!(
        "native-only model: {} layers, {} heads, d_model {}, vocab {}, \
         weights stored as {} ({} KiB read per decode tick)",
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_model,
        cfg.vocab,
        model.weight_dtype().name(),
        model.weight_bytes_per_token() / 1024,
    );
    let mut task = linear_transformer::data::CopyTask::new(cfg.max_len, 42);
    let (prompt, expected) = task.prompt();
    let mut sess = model.session();
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = sess.step(t);
    }
    let mut out = Vec::new();
    for _ in 0..expected.len() {
        let nxt = linear_transformer::sampling::argmax(&logits);
        out.push(nxt);
        logits = sess.step(nxt);
    }
    println!("native continuation: {out:?}");
    println!(
        "decode state: {} bytes, constant for all {} positions",
        sess.state_bytes(),
        cfg.max_len
    );
    println!("(untrained init — run `make artifacts` for the full PJRT-vs-native path)");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("no artifacts at {dir:?} ({e:#}); running the native-only demo");
            return native_only_demo();
        }
    };
    println!("PJRT platform: {}", rt.platform());

    // --- the model: copy task, linear attention ---
    let spec = rt
        .bundle
        .model("copy_linear")
        .expect("run `make artifacts` first")
        .clone();
    let weights = rt.load_weights("copy_linear")?;
    let cfg = spec.config.clone();
    println!(
        "model copy_linear: {} layers, {} heads, d_model {}, vocab {}",
        cfg.n_layers, cfg.n_heads, cfg.d_model, cfg.vocab
    );

    // a copy-task prompt: BOS + payload + SEP; the model should echo payload
    let mut task = linear_transformer::data::CopyTask::new(cfg.max_len, 42);
    let (prompt, expected) = task.prompt();
    println!("prompt: {prompt:?}");
    println!("expected continuation: {expected:?}");

    // --- path A: PJRT decode artifact (L1 Pallas -> L2 jax -> L3 rust) ---
    let art = rt.load("copy_decode_linear_b1")?;
    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head());
    let mut s = vec![0.0f32; l * h * dh * dh];
    let mut z = vec![0.0f32; l * h * dh];
    let mut pjrt_out: Vec<u32> = Vec::new();
    let mut tok = prompt[0] as i32;
    for i in 0.. {
        let mut inputs = params.clone();
        inputs.push(Value::I32(vec![1], vec![tok]));
        inputs.push(Value::I32(vec![1], vec![i as i32]));
        inputs.push(Value::F32(vec![l, 1, h, dh, dh], s.clone()));
        inputs.push(Value::F32(vec![l, 1, h, dh], z.clone()));
        let out = art.run(&inputs)?;
        s = out[1].as_f32()?.to_vec();
        z = out[2].as_f32()?.to_vec();
        if i + 1 < prompt.len() {
            tok = prompt[i + 1] as i32; // still consuming the prompt
        } else {
            let next = linear_transformer::sampling::argmax(out[0].as_f32()?);
            pjrt_out.push(next);
            if pjrt_out.len() == expected.len() {
                break;
            }
            tok = next as i32;
        }
    }
    println!("pjrt   continuation: {pjrt_out:?}");

    // --- path B: native rust RNN session, same weights ---
    let model = TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &weights)?;
    let mut sess = model.session();
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = sess.step(t);
    }
    let mut native_out = Vec::new();
    for _ in 0..expected.len() {
        let nxt = linear_transformer::sampling::argmax(&logits);
        native_out.push(nxt);
        logits = sess.step(nxt);
    }
    println!("native continuation: {native_out:?}");
    assert_eq!(
        pjrt_out, native_out,
        "the two inference paths must agree (greedy decoding)"
    );

    // --- the paper's punchline: decode state is O(1) in sequence length ---
    println!(
        "decode state: {} bytes, constant for all {} positions \
         (a softmax KV cache at full length would hold {} bytes)",
        sess.state_bytes(),
        cfg.max_len,
        cfg.max_len * cfg.d_model * 2 * cfg.n_layers * 4,
    );
    println!(
        "(weights are untrained init — run the train_copy_task example \
         for a model that actually copies)"
    );
    Ok(())
}
