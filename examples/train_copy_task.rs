//! End-to-end training driver — Figure 2 (convergence on the copy task).
//!
//! Trains the copy-task transformer with each attention family (linear /
//! softmax / lsh) through the `copy_<variant>_train` AOT artifacts
//! (fwd + bwd through the L1 Pallas kernels + RAdam, executed by the L3
//! PJRT runtime), using the paper's recipe: RAdam, lr 1e-3 dropped to
//! 1e-4 after 3000 updates, batches of duplicated symbol sequences.
//!
//! Outputs: results/fig2_<variant>.csv (step, loss, wall-clock) and a
//! checkpoint of the linear model that the serving/generation examples can
//! load. After training, the linear model is asked to actually *copy* a
//! held-out sequence and its accuracy is reported.
//!
//! Run: cargo run --release --example train_copy_task -- [steps] [variants]
//! e.g. cargo run --release --example train_copy_task -- 400 linear,softmax

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::TrainConfig;
use linear_transformer::nn::TransformerLM;
use linear_transformer::runtime::Runtime;
use linear_transformer::trainer::{self, Trainer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let variants: Vec<String> = args
        .get(2)
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| vec!["linear".into(), "softmax".into(), "lsh".into()]);

    std::fs::create_dir_all("results")?;
    let mut rt = Runtime::open("artifacts")?;

    for variant in &variants {
        eprintln!("=== training copy_{variant} for {steps} steps ===");
        let mut tr = Trainer::new(&mut rt, "copy", variant)?;
        let specs = tr.batch_specs().to_vec();
        let (b, n) = (specs[0].shape[0], specs[0].shape[1]);
        let cfg = TrainConfig {
            task: "copy".into(),
            variant: variant.clone(),
            steps,
            lr: 1e-3,
            lr_drop_step: Some(3000), // paper schedule
            log_every: 25,
            eval_every: 0,
            seed: 0,
            out_csv: Some(format!("results/fig2_{variant}.csv")),
            checkpoint: Some(format!("results/copy_{variant}_trained.ltw")),
        };
        let mut batch_fn = trainer::copy_batch_fn(n, b, cfg.seed);
        trainer::train_loop(&mut tr, &cfg, |s| batch_fn(s))?;
        eprintln!(
            "copy_{variant}: final loss {:.4}, {:.0} ms/step",
            tr.history.last().unwrap().loss,
            tr.mean_step_time().as_secs_f64() * 1e3
        );
    }

    // --- does the trained linear model actually copy? ---
    if variants.iter().any(|v| v == "linear") {
        let spec = rt.bundle.model("copy_linear").unwrap().clone();
        let weights =
            linear_transformer::weights::WeightBundle::load("results/copy_linear_trained.ltw")?;
        let model = TransformerLM::from_bundle(&spec.config, AttentionKind::Linear, &weights)?;
        let mut task = linear_transformer::data::CopyTask::new(spec.config.max_len, 1234);
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..20 {
            let (prompt, expected) = task.prompt();
            let mut sess = model.session();
            let mut logits = Vec::new();
            for &t in &prompt {
                logits = sess.step(t);
            }
            for &want in &expected {
                let got = linear_transformer::sampling::argmax(&logits);
                correct += usize::from(got == want);
                total += 1;
                logits = sess.step(want); // teacher-forced continuation
            }
        }
        println!(
            "copy accuracy after {steps} steps: {:.1}% ({} / {} symbols)",
            100.0 * correct as f64 / total as f64,
            correct,
            total
        );
    }
    println!("loss curves: results/fig2_<variant>.csv");
    Ok(())
}
