//! Serving demo: the coordinator under a bursty batched workload.
//!
//! Spins up the native engine + TCP server, fires concurrent client
//! requests over real sockets, and reports throughput / latency
//! percentiles / batch occupancy — the serving-systems view of the
//! paper's O(1)-per-token decode.
//!
//! Run: cargo run --release --example serve -- [n_requests] [max_batch]

use std::sync::Arc;

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::{ModelConfig, ServeConfig};
use linear_transformer::coordinator::engine::NativeEngine;
use linear_transformer::coordinator::request::GenerateRequest;
use linear_transformer::coordinator::server::{request_over_tcp, Server};
use linear_transformer::nn::TransformerLM;
use linear_transformer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let max_batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);

    // model: copy task with a trained checkpoint, the AOT init weights,
    // or (when neither PJRT nor artifacts are available) a random init —
    // the serving-systems demo only needs real weights for output quality
    let cfg = ModelConfig::small_copy();
    let ckpt = "results/copy_linear_trained.ltw";
    let weights = if std::path::Path::new(ckpt).exists() {
        Some(linear_transformer::weights::WeightBundle::load(ckpt)?)
    } else {
        match Runtime::open("artifacts").and_then(|rt| rt.load_weights("copy_linear")) {
            Ok(w) => Some(w),
            Err(e) => {
                eprintln!("[serve] no AOT weights ({e:#}); using random init");
                None
            }
        }
    };
    let model = match weights {
        Some(w) => TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &w)?,
        None => TransformerLM::init(&cfg, AttentionKind::Linear, 0),
    };

    let engine = Arc::new(NativeEngine::spawn(
        model,
        ServeConfig {
            max_batch,
            max_wait_us: 500,
            ..Default::default()
        },
    )?);
    let server = Server::start("127.0.0.1:0", engine.clone())?;
    println!("serving on {} (max_batch = {max_batch})", server.addr);

    // bursty client load: 4 client threads, each a burst of requests
    let addr = server.addr.to_string();
    let per_client = n_requests.div_ceil(4);
    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let reqs: Vec<GenerateRequest> = (0..per_client)
                    .map(|i| GenerateRequest {
                        id: (c * per_client + i) as u64,
                        prompt: vec![12, 3, 4, 5, 1],
                        max_new: 32,
                        temperature: 0.8,
                        top_k: 0,
                    })
                    .collect();
                request_over_tcp(&addr, &reqs).expect("client io")
            })
        })
        .collect();
    let mut total_tokens = 0usize;
    let mut completed = 0usize;
    for c in clients {
        for resp in c.join().unwrap() {
            assert!(resp.error.is_none(), "{:?}", resp.error);
            total_tokens += resp.tokens.len();
            completed += 1;
        }
    }
    let dt = t0.elapsed();
    let st = engine.stats();
    println!(
        "{completed} requests, {total_tokens} tokens in {:.2}s \
         -> {:.0} tok/s, {:.1} req/s",
        dt.as_secs_f64(),
        total_tokens as f64 / dt.as_secs_f64(),
        completed as f64 / dt.as_secs_f64()
    );
    println!(
        "engine: mean batch occupancy {:.2}/{max_batch}, latency {}",
        st.mean_batch_occupancy(),
        st.latency.summary()
    );
    server.stop();
    Ok(())
}
