//! GEMM/GEMV microkernel throughput: scalar vs SIMD ISA tiers, per
//! kernel × dtype × shape — the measurement behind the PR 10 microkernel
//! layer (`rust/src/simd.rs`), so the speedup is a number, not a claim.
//!
//! Three kernel families are swept on serving-relevant shapes (mnist
//! geometry: d_model 128, d_ff 512, vocab 256):
//!
//! * `vecmat_into_w` — the B=1 decode GEMV, once per weight dtype
//!   (f32/f16/bf16/int8). The weight-bandwidth-bound serving shape.
//! * `matmul_into_w` — the prefill/batched GEMM (cache-blocked packed
//!   path at m >= GEMM_PACK_MIN_ROWS), once per dtype.
//! * `batched_outer_acc` / `batched_contract` — the linear-attention
//!   state update and read-out (f32; their inner loop is the dispatched
//!   `axpy`).
//!
//! Every case runs on the scalar tier and, where the CPU supports it, on
//! the AVX2 tier via `simd::force_tier` — safe to flip inside one
//! process precisely because tiers are bitwise-identical, which this
//! bench also *asserts* on every kernel output before timing. GFLOP/s
//! counts one multiply + one add per element pair (2·m·k·n for GEMM).
//!
//! Emits machine-readable `BENCH_gemm.json`. `BENCH_QUICK=1` shrinks the
//! iteration counts to smoke-test size (the CI leg).
//!
//! Run: cargo run --release --example bench_gemm

use linear_transformer::benchkit::{bench, opts_from_env, BenchOpts};
use linear_transformer::json::{obj, Json};
use linear_transformer::rng::Rng;
use linear_transformer::simd::{self, IsaTier};
use linear_transformer::tensor::{
    batched_contract, batched_outer_acc, matmul_into_w, vecmat_into_w, WeightDtype, WeightMat,
};

/// One measured case, flattened for the JSON report.
struct Row {
    kernel: &'static str,
    dtype: &'static str,
    tier: &'static str,
    shape: String,
    gflops: f64,
    mean_us: f64,
}

fn tiers() -> Vec<IsaTier> {
    let mut t = vec![IsaTier::Scalar];
    if simd::avx2_supported() {
        t.push(IsaTier::Avx2);
    }
    t
}

fn gflops(flops: f64, mean_secs: f64) -> f64 {
    flops / mean_secs / 1e9
}

fn main() {
    let opts = opts_from_env();
    let configured = simd::configure(None);
    println!(
        "gemm/gemv microkernel bench: tiers {:?} (configured: {}, avx2 supported: {})",
        tiers().iter().map(|t| t.label()).collect::<Vec<_>>(),
        configured.label(),
        simd::avx2_supported()
    );
    if !simd::avx2_supported() {
        println!("(no AVX2 on this CPU: scalar tier only, cross-tier asserts skipped)");
    }

    let mut rng = Rng::new(1234);
    let mut rows: Vec<Row> = Vec::new();

    // --- B=1 decode GEMV: y[n] = x[k] @ w[k,n], per weight dtype ---
    let dtypes = [WeightDtype::F32, WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8];
    println!("\nvecmat_into_w (B=1 decode GEMV)");
    println!("{:>10} {:>6} {:>8} {:>10} {:>10}", "shape", "dtype", "tier", "GFLOP/s", "µs");
    for &(k, n) in &[(128usize, 512usize), (512, 128), (128, 256)] {
        let data = rng.normal_vec(k * n, 1.0);
        let x = rng.normal_vec(k, 1.0);
        for dtype in dtypes {
            let w = WeightMat::quantize(&data, k, n, dtype);
            let mut reference: Option<Vec<f32>> = None;
            for tier in tiers() {
                assert_eq!(simd::force_tier(tier), tier);
                let mut y = vec![0.0f32; n];
                vecmat_into_w(&mut y, &x, &w, k, n);
                match &reference {
                    None => reference = Some(y.clone()),
                    Some(want) => assert_eq!(&y, want, "tier changed a GEMV bit"),
                }
                let m = bench(
                    &format!("gemv {k}x{n} {} {}", dtype.name(), tier.label()),
                    opts,
                    || vecmat_into_w(&mut y, &x, &w, k, n),
                );
                let gf = gflops(2.0 * k as f64 * n as f64, m.mean_secs());
                println!(
                    "{:>10} {:>6} {:>8} {:>10.2} {:>10.1}",
                    format!("{k}x{n}"),
                    dtype.name(),
                    tier.label(),
                    gf,
                    m.mean_secs() * 1e6
                );
                rows.push(Row {
                    kernel: "vecmat_into_w",
                    dtype: dtype.name(),
                    tier: tier.label(),
                    shape: format!("1x{k}x{n}"),
                    gflops: gf,
                    mean_us: m.mean_secs() * 1e6,
                });
            }
        }
    }

    // --- prefill GEMM: c[m,n] = a[m,k] @ w[k,n] (packed path) ---
    println!("\nmatmul_into_w (prefill GEMM, cache-blocked packed path)");
    println!("{:>12} {:>6} {:>8} {:>10} {:>10}", "shape", "dtype", "tier", "GFLOP/s", "µs");
    for &(m, k, n) in &[(16usize, 128usize, 512usize), (64, 512, 128)] {
        let data = rng.normal_vec(k * n, 1.0);
        let a = rng.normal_vec(m * k, 1.0);
        for dtype in dtypes {
            let w = WeightMat::quantize(&data, k, n, dtype);
            let mut reference: Option<Vec<f32>> = None;
            for tier in tiers() {
                assert_eq!(simd::force_tier(tier), tier);
                let mut c = vec![0.0f32; m * n];
                matmul_into_w(&mut c, &a, &w, m, k, n);
                match &reference {
                    None => reference = Some(c.clone()),
                    Some(want) => assert_eq!(&c, want, "tier changed a GEMM bit"),
                }
                let meas = bench(
                    &format!("gemm {m}x{k}x{n} {} {}", dtype.name(), tier.label()),
                    opts,
                    || matmul_into_w(&mut c, &a, &w, m, k, n),
                );
                let gf = gflops(2.0 * m as f64 * k as f64 * n as f64, meas.mean_secs());
                println!(
                    "{:>12} {:>6} {:>8} {:>10.2} {:>10.1}",
                    format!("{m}x{k}x{n}"),
                    dtype.name(),
                    tier.label(),
                    gf,
                    meas.mean_secs() * 1e6
                );
                rows.push(Row {
                    kernel: "matmul_into_w",
                    dtype: dtype.name(),
                    tier: tier.label(),
                    shape: format!("{m}x{k}x{n}"),
                    gflops: gf,
                    mean_us: meas.mean_secs() * 1e6,
                });
            }
        }
    }

    // --- batched linear-attention kernels (f32, axpy inner loop) ---
    println!("\nbatched attention kernels (B lanes, d_head x d_head state)");
    println!("{:>12} {:>18} {:>8} {:>10} {:>10}", "shape", "kernel", "tier", "GFLOP/s", "µs");
    for &(b, d, m) in &[(16usize, 32usize, 32usize), (64, 32, 32)] {
        let kvec = rng.normal_vec(b * d, 1.0);
        let v = rng.normal_vec(b * m, 1.0);
        let q = rng.normal_vec(b * d, 1.0);
        let s0 = rng.normal_vec(b * d * m, 1.0);
        bench_attention_pair(&mut rows, opts, b, d, m, &kvec, &v, &q, &s0);
    }

    // leave the process on the configured tier, not whatever the sweep
    // ended on
    simd::configure(None);

    let report = obj(vec![
        ("bench", Json::Str("gemm_microkernels".into())),
        ("avx2_supported", Json::Bool(simd::avx2_supported())),
        ("configured_tier", Json::Str(configured.label().into())),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("kernel", Json::Str(r.kernel.into())),
                            ("dtype", Json::Str(r.dtype.into())),
                            ("tier", Json::Str(r.tier.into())),
                            ("shape", Json::Str(r.shape.clone())),
                            ("gflops", Json::Num(r.gflops)),
                            ("mean_us", Json::Num(r.mean_us)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_gemm.json", report.to_string()) {
        Ok(()) => println!("\n[json] BENCH_gemm.json"),
        Err(e) => eprintln!("warning: could not write BENCH_gemm.json: {e}"),
    }
}

/// Bench `batched_outer_acc` + `batched_contract` at one (b, d, m) shape
/// across the available tiers, asserting cross-tier bit-identity.
#[allow(clippy::too_many_arguments)]
fn bench_attention_pair(
    rows: &mut Vec<Row>,
    opts: BenchOpts,
    b: usize,
    d: usize,
    m: usize,
    kvec: &[f32],
    v: &[f32],
    q: &[f32],
    s0: &[f32],
) {
    let mut outer_ref: Option<Vec<f32>> = None;
    let mut contract_ref: Option<Vec<f32>> = None;
    for tier in tiers() {
        assert_eq!(simd::force_tier(tier), tier);

        let mut s = s0.to_vec();
        batched_outer_acc(&mut s, kvec, v, b, d, m);
        match &outer_ref {
            None => outer_ref = Some(s.clone()),
            Some(want) => assert_eq!(&s, want, "tier changed an outer_acc bit"),
        }
        let meas = bench(&format!("outer_acc {b}x{d}x{m} {}", tier.label()), opts, || {
            let mut st = s0.to_vec();
            batched_outer_acc(&mut st, kvec, v, b, d, m);
            std::hint::black_box(&st);
        });
        let gf = gflops(2.0 * (b * d * m) as f64, meas.mean_secs());
        println!(
            "{:>12} {:>18} {:>8} {:>10.2} {:>10.1}",
            format!("{b}x{d}x{m}"),
            "batched_outer_acc",
            tier.label(),
            gf,
            meas.mean_secs() * 1e6
        );
        rows.push(Row {
            kernel: "batched_outer_acc",
            dtype: "f32",
            tier: tier.label(),
            shape: format!("{b}x{d}x{m}"),
            gflops: gf,
            mean_us: meas.mean_secs() * 1e6,
        });

        let mut out = vec![0.0f32; b * m];
        batched_contract(&mut out, q, &s, b, d, m);
        match &contract_ref {
            None => contract_ref = Some(out.clone()),
            Some(want) => assert_eq!(&out, want, "tier changed a contract bit"),
        }
        let meas = bench(&format!("contract {b}x{d}x{m} {}", tier.label()), opts, || {
            batched_contract(&mut out, q, &s, b, d, m);
        });
        let gf = gflops(2.0 * (b * d * m) as f64, meas.mean_secs());
        println!(
            "{:>12} {:>18} {:>8} {:>10.2} {:>10.1}",
            format!("{b}x{d}x{m}"),
            "batched_contract",
            tier.label(),
            gf,
            meas.mean_secs() * 1e6
        );
        rows.push(Row {
            kernel: "batched_contract",
            dtype: "f32",
            tier: tier.label(),
            shape: format!("{b}x{d}x{m}"),
            gflops: gf,
            mean_us: meas.mean_secs() * 1e6,
        });
    }
}
