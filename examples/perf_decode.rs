//! Decode throughput: batched structure-of-arrays decode vs the per-slot
//! scalar loop, at B ∈ {1, 4, 16, 64} — plus time-to-first-token for a
//! long prompt (per-tick walk vs chunked prefill) and a worker-pool
//! thread sweep over both hot paths.
//!
//! The per-slot loop is what the seed engine did (B independent
//! `DecodeSession`s advanced one at a time — B GEMVs per projection); the
//! batched path is one `BatchedDecodeSession` advancing all lanes through
//! single `[B, ·]` GEMMs. Every weight matrix is read once per tick
//! instead of B times, which is the whole game on a weight-bandwidth-bound
//! decode. The TTFT section ingests a 512-token prompt both ways: one
//! engine tick per token (lm-head every tick) vs `prefill_row` (chunked
//! GEMMs, lm-head once). The thread sweep reruns the B=16 decode tick and
//! the 512-token prefill at threads ∈ {1, 2, 4, max}; pooled kernels are
//! bit-identical to serial, so the sweep asserts unchanged first tokens
//! while measuring the multi-core speedup. The mixed-traffic section
//! measures what incremental prefill scheduling buys: resident-lane
//! decode tick latency (p50/max) while a 512-token prompt admits, with
//! the prompt landing in one shot vs one `PREFILL_CHUNK` per tick.
//! The prefix-cache section measures what the prefix-reuse state cache
//! buys: cold vs warm TTFT for a request sharing a 512-token prefix
//! (warm = restore the fixed-size lane snapshot, prefill only the
//! suffix), first logits asserted bit-identical.
//! The dtype sweep reruns the B=1 decode tick with weights stored at
//! f32/f16/bf16/int8: the tick streams every projection matrix once, so
//! `weight_bytes_per_token` IS the bytes moved per tick, and halving it
//! (f16) is the point on a weight-bandwidth-bound decode. Activations
//! stay f32 throughout; tok/s plus the bytes ratio vs f32 are reported.
//! The linear-vs-softmax section contrasts the two serving backends —
//! the paper's O(1)-vs-O(t) per-token claim as a measurement: B=1
//! per-tick latency near generated length N and lane-snapshot bytes at
//! N for both backends, N ∈ {64, 128, 256, 512}.
//! Emits machine-readable `BENCH_decode.json`.
//!
//! Run: cargo run --release --example perf_decode -- [steps]

use std::sync::Arc;

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::ModelConfig;
use linear_transformer::json::{obj, Json};
use linear_transformer::nn::TransformerLM;
use linear_transformer::parallel::ThreadPool;
use linear_transformer::tensor::WeightDtype;

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let cfg = ModelConfig::mnist();
    let steps = steps.min(cfg.max_len - 1);
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 1);

    // resolve + log the ISA tier up front: every number below depends on
    // which microkernels ran (LINTRA_SIMD=0 forces the scalar tier;
    // outputs are bit-identical either way)
    let isa_tier = linear_transformer::simd::configure(None);
    println!(
        "decode throughput, mnist geometry (d_model {}, {} layers), {} steps/lane, simd={}",
        cfg.d_model,
        cfg.n_layers,
        steps,
        isa_tier.label()
    );
    println!(
        "{:>5} {:>16} {:>16} {:>9}",
        "B", "per-slot tok/s", "batched tok/s", "speedup"
    );

    let mut rows = Vec::new();
    for &b in &[1usize, 4, 16, 64] {
        // per-slot: B independent sessions advanced one at a time (seed behavior)
        let mut sessions: Vec<_> = (0..b).map(|_| model.session()).collect();
        let mut tokens: Vec<u32> = (0..b).map(|r| (r % cfg.vocab) as u32).collect();
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            for (sess, tok) in sessions.iter_mut().zip(tokens.iter_mut()) {
                let logits = sess.step(*tok);
                *tok = linear_transformer::sampling::argmax(&logits);
            }
        }
        let per_slot = (b * steps) as f64 / t0.elapsed().as_secs_f64();

        // batched: one session, all lanes per tick (no pool here — this
        // table isolates the batching win; see the thread sweep below)
        let mut batched = model.batched_session_with_pool(b, None);
        for _ in 0..b {
            batched.alloc_row().expect("capacity");
        }
        let mut tokens: Vec<u32> = (0..b).map(|r| (r % cfg.vocab) as u32).collect();
        let vocab = cfg.vocab;
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let logits = batched.step_batch(&tokens);
            for (r, tok) in tokens.iter_mut().enumerate() {
                *tok = linear_transformer::sampling::argmax(&logits[r * vocab..(r + 1) * vocab]);
            }
        }
        let batched_tps = (b * steps) as f64 / t0.elapsed().as_secs_f64();

        let speedup = batched_tps / per_slot;
        println!("{b:>5} {per_slot:>16.0} {batched_tps:>16.0} {speedup:>8.2}x");
        rows.push(Json::Obj(
            [
                ("batch".to_string(), Json::Num(b as f64)),
                ("per_slot_tok_s".to_string(), Json::Num(per_slot)),
                ("batched_tok_s".to_string(), Json::Num(batched_tps)),
                ("speedup".to_string(), Json::Num(speedup)),
            ]
            .into_iter()
            .collect(),
        ));
    }

    // --- time-to-first-token: per-tick prompt walk vs chunked prefill ---
    let prompt_len = 512.min(cfg.max_len - 1);
    let prompt: Vec<u32> = (0..prompt_len).map(|i| (i % cfg.vocab) as u32).collect();

    let mut per_tick = model.batched_session_with_pool(1, None);
    per_tick.alloc_row().expect("capacity");
    let t0 = std::time::Instant::now();
    let mut tick_logits = Vec::new();
    for &t in &prompt {
        tick_logits = per_tick.step_batch(&[t]);
    }
    let per_tick_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut prefilled = model.batched_session_with_pool(1, None);
    prefilled.alloc_row().expect("capacity");
    let t0 = std::time::Instant::now();
    let prefill_logits = prefilled.prefill_row(0, &prompt);
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

    // the two ingestion paths must agree on the first sampled token
    let tick_tok = linear_transformer::sampling::argmax(&tick_logits);
    let prefill_tok = linear_transformer::sampling::argmax(&prefill_logits);
    assert_eq!(
        tick_tok, prefill_tok,
        "prefill must reproduce the per-tick first token"
    );

    let ttft_speedup = per_tick_ms / prefill_ms;
    println!(
        "\nTTFT, {prompt_len}-token prompt: per-tick {per_tick_ms:.1} ms, \
         prefill {prefill_ms:.1} ms ({ttft_speedup:.2}x)"
    );

    // --- worker-pool thread sweep: B=16 decode tick + 512-token TTFT ---
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut sweep: Vec<usize> = [1usize, 2, 4, max_threads]
        .into_iter()
        .filter(|&t| t <= max_threads)
        .collect();
    sweep.dedup();
    println!(
        "\nthread sweep ({} cores available): B=16 decode + {prompt_len}-token prefill",
        max_threads
    );
    println!(
        "{:>8} {:>16} {:>9} {:>13} {:>9}",
        "threads", "b16 tok/s", "speedup", "prefill ms", "speedup"
    );
    let sweep_b = 16usize;
    let mut base_tok_s = 0.0f64;
    let mut base_prefill_ms = 0.0f64;
    let mut serial_first_token = None;
    let mut sweep_rows = Vec::new();
    for &threads in &sweep {
        let pool = if threads == 1 {
            None
        } else {
            Some(Arc::new(ThreadPool::new(threads)))
        };

        // B=16 decode tick
        let mut sess = model.batched_session_with_pool(sweep_b, pool.clone());
        for _ in 0..sweep_b {
            sess.alloc_row().expect("capacity");
        }
        let mut tokens: Vec<u32> = (0..sweep_b).map(|r| (r % cfg.vocab) as u32).collect();
        let vocab = cfg.vocab;
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let logits = sess.step_batch(&tokens);
            for (r, tok) in tokens.iter_mut().enumerate() {
                *tok = linear_transformer::sampling::argmax(&logits[r * vocab..(r + 1) * vocab]);
            }
        }
        let tok_s = (sweep_b * steps) as f64 / t0.elapsed().as_secs_f64();

        // 512-token TTFT via prefill
        let mut sess = model.batched_session_with_pool(1, pool);
        sess.alloc_row().expect("capacity");
        let t0 = std::time::Instant::now();
        let logits = sess.prefill_row(0, &prompt);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let first = linear_transformer::sampling::argmax(&logits);
        match serial_first_token {
            None => serial_first_token = Some(first),
            // pooled kernels are bit-identical: the sweep must not move a token
            Some(t) => assert_eq!(t, first, "thread count changed the first sampled token"),
        }

        if threads == 1 {
            base_tok_s = tok_s;
            base_prefill_ms = ms;
        }
        let tok_speedup = tok_s / base_tok_s;
        let ttft_thread_speedup = base_prefill_ms / ms;
        println!(
            "{threads:>8} {tok_s:>16.0} {tok_speedup:>8.2}x {ms:>13.1} {ttft_thread_speedup:>8.2}x"
        );
        sweep_rows.push(Json::Obj(
            [
                ("threads".to_string(), Json::Num(threads as f64)),
                ("b16_tok_s".to_string(), Json::Num(tok_s)),
                ("b16_speedup".to_string(), Json::Num(tok_speedup)),
                ("prefill_ms".to_string(), Json::Num(ms)),
                ("prefill_speedup".to_string(), Json::Num(ttft_thread_speedup)),
            ]
            .into_iter()
            .collect(),
        ));
    }

    // --- mixed traffic: resident decode tick latency while a 512-token
    // prompt admits, one-shot vs incremental (1 chunk/tick) ---
    //
    // Mirrors the engine's schedule at the session level so the numbers
    // are deterministic: B_RES resident lanes prefix-step every tick; a
    // new lane admits its prompt either in one prefill_row call (the
    // tick that admits it stalls for the whole prompt) or one
    // PREFILL_CHUNK per tick via prefill_row_partial (admission work
    // bounded per tick). Reported: resident per-tick latency p50/max in
    // each mode. The admitted lane's first token is asserted identical.
    const B_RES: usize = 8;
    let chunk = linear_transformer::nn::PREFILL_CHUNK;
    let n_chunks = prompt_len.div_ceil(chunk);
    let warm = 16usize;

    let run_mixed = |incremental: bool| -> (Vec<f64>, u32) {
        let vocab = cfg.vocab;
        let mut sess = model.batched_session_with_pool(B_RES + 1, None);
        for _ in 0..B_RES {
            sess.alloc_row().expect("capacity");
        }
        let mut tokens: Vec<u32> = (0..B_RES).map(|r| (r % cfg.vocab) as u32).collect();
        let mut tick_ms = Vec::new();
        let mut first_token = 0u32;
        // warm ticks, then the admission ticks, then a few cool-down ticks
        for tick in 0..warm + n_chunks + 4 {
            let t0 = std::time::Instant::now();
            if tick == warm {
                let admitted = sess.alloc_row().expect("capacity");
                if !incremental {
                    // one-shot: the whole prompt lands inside this tick
                    let logits = sess.prefill_row(admitted, &prompt);
                    first_token = linear_transformer::sampling::argmax(&logits);
                }
            }
            if incremental && (warm..warm + n_chunks).contains(&tick) {
                let off = (tick - warm) * chunk;
                let end = (off + chunk).min(prompt_len);
                let finish = end == prompt_len;
                let logits = sess.prefill_row_partial(B_RES, &prompt[off..end], finish);
                if let Some(l) = logits {
                    first_token = linear_transformer::sampling::argmax(&l);
                }
            }
            // the resident lanes' decode tick (prefix step: the admitting
            // lane joins only after its final prompt position lands)
            let logits = sess.step_batch(&tokens);
            for (r, tok) in tokens.iter_mut().enumerate() {
                *tok = linear_transformer::sampling::argmax(&logits[r * vocab..(r + 1) * vocab]);
            }
            tick_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        (tick_ms, first_token)
    };

    let (oneshot_ticks, oneshot_first) = run_mixed(false);
    let (incr_ticks, incr_first) = run_mixed(true);
    assert_eq!(
        oneshot_first, incr_first,
        "incremental admission must reproduce the one-shot first token"
    );
    let stats_of = |ticks: &[f64]| {
        let mut s: Vec<f64> = ticks.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        (s[s.len() / 2], s[s.len() - 1]) // (p50, max)
    };
    let (oneshot_p50, oneshot_max) = stats_of(&oneshot_ticks);
    let (incr_p50, incr_max) = stats_of(&incr_ticks);
    println!("\nmixed traffic ({B_RES} resident lanes, {prompt_len}-token prompt admitting):");
    println!("{:>12} {:>14} {:>14}", "mode", "tick p50 ms", "tick max ms");
    println!("{:>12} {oneshot_p50:>13.2} {oneshot_max:>13.2}", "one-shot");
    println!("{:>12} {incr_p50:>13.2} {incr_max:>13.2}", "incremental");
    println!(
        "(one-shot's max tick absorbs the whole prompt; incremental bounds it \
         to one {chunk}-token chunk per tick)"
    );

    // --- prefix cache: cold vs warm TTFT for a shared 512-token prefix ---
    //
    // The serving engine's --state-cache-mb path at the session level:
    // a donor request ingests a shared prefix (system prompt / few-shot
    // template) once and its fixed-size lane state is snapshotted
    // (export_lane); a warm admission restores the snapshot (a memcpy)
    // and prefills only its private suffix, where a cold admission
    // prefills prefix + suffix. Restore is bit-identical to prefilling
    // the prefix in place, asserted on the full logits row.
    let shared_len = prompt_len;
    let suffix_len = 32.min(cfg.max_len - shared_len - 1);
    let shared: Vec<u32> = (0..shared_len).map(|i| ((i * 7) % cfg.vocab) as u32).collect();
    let suffix: Vec<u32> = (0..suffix_len).map(|i| ((i * 11 + 3) % cfg.vocab) as u32).collect();
    let full: Vec<u32> = shared.iter().chain(&suffix).copied().collect();

    let mut cold = model.batched_session_with_pool(1, None);
    cold.alloc_row().expect("capacity");
    let t0 = std::time::Instant::now();
    let cold_logits = cold.prefill_row(0, &full);
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;

    // the donor's one-time ingestion (what the first request pays anyway)
    let mut donor = model.batched_session_with_pool(1, None);
    donor.alloc_row().expect("capacity");
    donor.prefill_row_partial(0, &shared, false);
    let snap = donor.export_lane(0);

    let mut warm = model.batched_session_with_pool(1, None);
    warm.alloc_row().expect("capacity");
    let t0 = std::time::Instant::now();
    warm.import_lane(0, &snap);
    let warm_logits = warm
        .prefill_row_partial(0, &suffix, true)
        .expect("finishing slice returns logits");
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(
        warm_logits, cold_logits,
        "restored-prefix TTFT must be bit-identical to the cold path"
    );
    let prefix_speedup = cold_ms / warm_ms;
    println!(
        "\nprefix cache, {shared_len}-token shared prefix + {suffix_len}-token suffix: \
         cold {cold_ms:.1} ms, warm {warm_ms:.1} ms ({prefix_speedup:.2}x; \
         snapshot {} KiB)",
        snap.bytes() / 1024
    );

    // --- weight-dtype sweep: B=1 decode, weight bytes moved per tick ---
    //
    // B=1 is the weight-bandwidth-bound extreme: every tick reads every
    // projection matrix once to produce one token, so tok/s tracks
    // 1 / weight_bytes_per_token. f32 is the bitwise reference; the
    // narrow dtypes trade the documented logit tolerance for bandwidth.
    println!("\nweight-dtype sweep: B=1 decode, {steps} ticks");
    println!(
        "{:>6} {:>14} {:>12} {:>13}",
        "dtype", "KiB/tick", "tok/s", "bytes vs f32"
    );
    let mut dtype_rows = Vec::new();
    let mut f32_bytes = 0usize;
    let mut f16_bytes = 0usize;
    for dtype in [
        WeightDtype::F32,
        WeightDtype::F16,
        WeightDtype::Bf16,
        WeightDtype::Int8,
    ] {
        let mut m = TransformerLM::init(&cfg, AttentionKind::Linear, 1);
        m.cast_weights(dtype);
        let bytes = m.weight_bytes_per_token();
        if dtype == WeightDtype::F32 {
            f32_bytes = bytes;
        }
        if dtype == WeightDtype::F16 {
            f16_bytes = bytes;
        }
        let mut sess = m.batched_session_with_pool(1, None);
        sess.alloc_row().expect("capacity");
        let mut tok = 0u32;
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let logits = sess.step_batch(&[tok]);
            tok = linear_transformer::sampling::argmax(&logits);
        }
        let tok_s = steps as f64 / t0.elapsed().as_secs_f64();
        let ratio = bytes as f64 / f32_bytes as f64;
        println!(
            "{:>6} {:>14.1} {:>12.0} {:>12.2}x",
            dtype.name(),
            bytes as f64 / 1024.0,
            tok_s,
            ratio
        );
        dtype_rows.push(Json::Obj(
            [
                ("dtype".to_string(), Json::Str(dtype.name().into())),
                ("weight_bytes_per_tick".to_string(), Json::Num(bytes as f64)),
                ("tok_s".to_string(), Json::Num(tok_s)),
                ("bytes_vs_f32".to_string(), Json::Num(ratio)),
            ]
            .into_iter()
            .collect(),
        ));
    }
    assert!(
        f32_bytes >= 2 * f16_bytes,
        "f16 must at least halve the weight bytes per tick \
         ({f32_bytes} vs {f16_bytes})"
    );

    // --- linear vs softmax serving backends: per-tick latency and
    // snapshot bytes vs generated length N ---
    //
    // The paper's Tables 4/5 story as a serving measurement: both
    // backends run the exact same projection/FF/lm-head GEMMs behind the
    // same `DecodeBackend` trait; the divergence is pure attention-core
    // cost (O(1) state update vs attending over N cached rows) and lane
    // state size (constant (S, Z) vs N K/V rows). Per-tick latency is
    // the mean over the trailing ticks approaching each N — the
    // steady-state cost at that depth; snapshot bytes are
    // `LaneSnapshot::bytes()` at N, i.e. what the prefix-reuse state
    // cache pays per deposited entry on each backend.
    let softmax_model = TransformerLM::init(&cfg, AttentionKind::Softmax, 1);
    println!("\nlinear vs softmax backend: B=1 per-tick ms and snapshot bytes vs N");
    println!(
        "{:>6} {:>15} {:>16} {:>14} {:>15}",
        "N", "linear ms/tick", "softmax ms/tick", "linear snap B", "softmax snap B"
    );
    let mut lvs_rows = Vec::new();
    let mut prev_softmax_snap = 0usize;
    for &n_raw in &[64usize, 128, 256, 512] {
        let n = n_raw.min(cfg.max_len - 1);
        let tail = 16usize.min(n / 2);

        let (lin_ms, lin_snap) = {
            let mut sess = model.batched_session_with_pool(1, None);
            sess.alloc_row().expect("capacity");
            let mut tok = 0u32;
            for _ in 0..n - tail {
                let logits = sess.step_batch(&[tok]);
                tok = linear_transformer::sampling::argmax(&logits);
            }
            let t0 = std::time::Instant::now();
            for _ in 0..tail {
                let logits = sess.step_batch(&[tok]);
                tok = linear_transformer::sampling::argmax(&logits);
            }
            let ms = t0.elapsed().as_secs_f64() * 1e3 / tail as f64;
            (ms, sess.export_lane(0).bytes())
        };

        let (soft_ms, soft_snap) = {
            let mut sess = softmax_model.batched_softmax_session_with_pool(1, None);
            sess.alloc_row().expect("capacity");
            let mut tok = 0u32;
            for _ in 0..n - tail {
                let logits = sess.step_batch(&[tok]);
                tok = linear_transformer::sampling::argmax(&logits);
            }
            let t0 = std::time::Instant::now();
            for _ in 0..tail {
                let logits = sess.step_batch(&[tok]);
                tok = linear_transformer::sampling::argmax(&logits);
            }
            let ms = t0.elapsed().as_secs_f64() * 1e3 / tail as f64;
            (ms, sess.export_lane(0).bytes())
        };

        // the asymptotics the section exists to show: linear's snapshot
        // is depth-independent, softmax's grows linearly with N
        assert!(
            soft_snap > prev_softmax_snap,
            "softmax snapshot must grow with N ({prev_softmax_snap} -> {soft_snap})"
        );
        prev_softmax_snap = soft_snap;

        println!(
            "{n:>6} {lin_ms:>15.3} {soft_ms:>16.3} {lin_snap:>14} {soft_snap:>15}"
        );
        lvs_rows.push(Json::Obj(
            [
                ("n".to_string(), Json::Num(n as f64)),
                ("linear_ms_per_tick".to_string(), Json::Num(lin_ms)),
                ("softmax_ms_per_tick".to_string(), Json::Num(soft_ms)),
                ("linear_snapshot_bytes".to_string(), Json::Num(lin_snap as f64)),
                ("softmax_snapshot_bytes".to_string(), Json::Num(soft_snap as f64)),
                ("softmax_over_linear_ms".to_string(), Json::Num(soft_ms / lin_ms)),
            ]
            .into_iter()
            .collect(),
        ));
    }

    let report = obj(vec![
        ("model", Json::Str("mnist".into())),
        ("simd_tier", Json::Str(isa_tier.label().into())),
        ("steps_per_lane", Json::Num(steps as f64)),
        ("results", Json::Arr(rows)),
        (
            "ttft",
            obj(vec![
                ("prompt_len", Json::Num(prompt_len as f64)),
                ("per_tick_ms", Json::Num(per_tick_ms)),
                ("prefill_ms", Json::Num(prefill_ms)),
                ("speedup", Json::Num(ttft_speedup)),
            ]),
        ),
        ("thread_sweep", Json::Arr(sweep_rows)),
        ("dtype_sweep", Json::Arr(dtype_rows)),
        ("linear_vs_softmax", Json::Arr(lvs_rows)),
        (
            "mixed_traffic",
            obj(vec![
                ("resident_lanes", Json::Num(B_RES as f64)),
                ("prompt_len", Json::Num(prompt_len as f64)),
                ("oneshot_tick_p50_ms", Json::Num(oneshot_p50)),
                ("oneshot_tick_max_ms", Json::Num(oneshot_max)),
                ("incremental_tick_p50_ms", Json::Num(incr_p50)),
                ("incremental_tick_max_ms", Json::Num(incr_max)),
                ("stall_reduction", Json::Num(oneshot_max / incr_max)),
            ]),
        ),
        (
            "prefix_cache",
            obj(vec![
                ("prefix_len", Json::Num(shared_len as f64)),
                ("suffix_len", Json::Num(suffix_len as f64)),
                ("cold_ttft_ms", Json::Num(cold_ms)),
                ("warm_ttft_ms", Json::Num(warm_ms)),
                ("speedup", Json::Num(prefix_speedup)),
                ("snapshot_bytes", Json::Num(snap.bytes() as f64)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_decode.json", report.to_string()) {
        Ok(()) => println!("[json] BENCH_decode.json"),
        Err(e) => eprintln!("warning: could not write BENCH_decode.json: {e}"),
    }
}
