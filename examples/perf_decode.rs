use linear_transformer::attention::AttentionKind;
use linear_transformer::config::ModelConfig;
use linear_transformer::nn::TransformerLM;
fn main() {
    let cfg = ModelConfig::mnist();
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 1);
    let mut sess = model.session();
    let mut logits = sess.step(0);
    let t0 = std::time::Instant::now();
    let steps = 2000usize.min(cfg.max_len - 1);
    for _ in 0..steps {
        let px = linear_transformer::sampling::argmax(&logits);
        logits = sess.step(px % 255);
        if sess.history.len() + 1 >= cfg.max_len { break; }
    }
    println!("linear decode: {:.1} us/token", t0.elapsed().as_secs_f64() * 1e6 / sess.history.len() as f64);
}
