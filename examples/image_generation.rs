//! Autoregressive image generation (§4.2) — the paper's flagship use case.
//!
//! Generates MNIST-like 784-pixel images with the linear-attention model
//! through the native RNN decode path, reports images/sec, demonstrates
//! image *completion* (occluded top half -> generated bottom half, Figure 3)
//! via the PJRT prefill artifact + decode steps, and writes PGM sample
//! grids under results/samples/.
//!
//! Run: cargo run --release --example image_generation -- [n_images] [weights.ltw]

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::ModelConfig;
use linear_transformer::data::{images::write_pnm, ImageDataset, ImageKind};
use linear_transformer::nn::TransformerLM;
use linear_transformer::rng::Rng;
use linear_transformer::runtime::{Runtime, Value};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_images: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    std::fs::create_dir_all("results/samples")?;

    let mut rt = Runtime::open("artifacts")?;
    let cfg = ModelConfig::mnist();
    let weights = match args.get(2) {
        Some(path) => linear_transformer::weights::WeightBundle::load(path)?,
        None => rt.load_weights("mnist_linear")?,
    };
    let model = TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &weights)?;
    let mut rng = Rng::new(7);

    // --- unconditional sampling through the RNN (constant memory/pixel) ---
    let t0 = std::time::Instant::now();
    let mut first_img: Vec<u32> = Vec::new();
    for i in 0..n_images {
        let mut sess = model.session();
        let mut logits = sess.step(0); // start-of-image token
        let mut pixels = Vec::with_capacity(784);
        for _ in 0..783 {
            let px = linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng);
            pixels.push(px);
            logits = sess.step(px);
        }
        pixels.push(linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng));
        if i == 0 {
            first_img = pixels.clone();
        }
        write_pnm(
            &format!("results/samples/uncond_{i}.pgm"),
            &pixels,
            ImageKind::MnistLike,
        )?;
    }
    let dt = t0.elapsed();
    println!(
        "unconditional: {n_images} images in {:.2}s -> {:.2} images/sec \
         (decode state {} bytes/image, constant from pixel 1 to 784)",
        dt.as_secs_f64(),
        n_images as f64 / dt.as_secs_f64(),
        model.session().state_bytes(),
    );
    let _ = first_img;

    // --- completion via prefill (Figure 3): occlude, prefill, continue ---
    let prefill = rt.load("mnist_prefill_b1")?;
    let decode = rt.load("mnist_decode_linear_b1")?;
    let spec = rt.bundle.model("mnist_linear").unwrap().clone();
    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let plen = prefill.spec.inputs.last().unwrap().shape[1];
    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head());

    let mut data = ImageDataset::new(ImageKind::MnistLike, 99);
    let (orig, _) = data.sample();
    write_pnm("results/samples/completion_original.pgm", &orig, ImageKind::MnistLike)?;
    let mut occluded = orig.clone();
    for p in occluded.iter_mut().skip(plen) {
        *p = 0;
    }
    write_pnm("results/samples/completion_occluded.pgm", &occluded, ImageKind::MnistLike)?;

    // prefill consumes [0, px_0..px_{plen-2}] (the shifted input stream)
    let mut prompt: Vec<i32> = vec![0];
    prompt.extend(orig[..plen - 1].iter().map(|&p| p as i32));
    let t1 = std::time::Instant::now();
    let mut inputs = params.clone();
    inputs.push(Value::I32(vec![1, plen], prompt));
    let out = prefill.run(&inputs)?;
    let mut s = out[1].as_f32()?.to_vec();
    let mut z = out[2].as_f32()?.to_vec();
    let prefill_time = t1.elapsed();

    let mut completed = orig[..plen].to_vec();
    let mut tok = orig[plen - 1] as i32;
    let t2 = std::time::Instant::now();
    for pos in plen..784 {
        let mut inputs = params.clone();
        inputs.push(Value::I32(vec![1], vec![tok]));
        inputs.push(Value::I32(vec![1], vec![pos as i32]));
        inputs.push(Value::F32(vec![l, 1, h, dh, dh], s));
        inputs.push(Value::F32(vec![l, 1, h, dh], z));
        let out = decode.run(&inputs)?;
        let px = linear_transformer::sampling::sample_logits(out[0].as_f32()?, 1.0, &mut rng);
        completed.push(px);
        tok = px as i32;
        s = out[1].as_f32()?.to_vec();
        z = out[2].as_f32()?.to_vec();
    }
    write_pnm("results/samples/completion_generated.pgm", &completed, ImageKind::MnistLike)?;
    println!(
        "completion via PJRT: prefill of {plen} px in {:?} (parallel), \
         {} px decoded in {:?} ({:.1} px/s)",
        prefill_time,
        784 - plen,
        t2.elapsed(),
        (784 - plen) as f64 / t2.elapsed().as_secs_f64()
    );
    println!("samples written under results/samples/*.pgm");
    Ok(())
}
