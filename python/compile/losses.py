"""Training losses: cross-entropy, bits/dim, and a from-scratch CTC.

CTC (Graves et al., 2006) is required by the paper's speech experiment
(Table 3). jax ships no CTC in this environment's feature set we rely on,
so the forward algorithm is implemented here directly: log-space alpha
recursion over the blank-extended label sequence, scanned over time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def cross_entropy(logits: jax.Array, targets: jax.Array, mask: jax.Array | None = None):
    """Mean token cross-entropy. logits [..., V], targets [...] int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def bits_per_dim(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """bits/dim for autoregressive image models: nats -> bits."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean() / jnp.log(2.0)


def _extend_labels(labels: jax.Array, blank: int) -> jax.Array:
    """[a, b, c] -> [blank, a, blank, b, blank, c, blank]."""
    s = labels.shape[-1]
    ext = jnp.full(labels.shape[:-1] + (2 * s + 1,), blank, labels.dtype)
    return ext.at[..., 1::2].set(labels)


def ctc_loss(
    log_probs: jax.Array,  # [B, T, V] log-softmaxed frame posteriors
    frame_lengths: jax.Array,  # [B] int32, valid frames per sample
    labels: jax.Array,  # [B, S] int32, padded with `blank`
    label_lengths: jax.Array,  # [B] int32
    blank: int = 0,
) -> jax.Array:
    """Mean negative log-likelihood under the CTC alignment lattice."""
    b, t, v = log_probs.shape
    ext = _extend_labels(labels, blank)  # [B, 2S+1]
    u = ext.shape[-1]

    # transition structure: alpha[s] <- alpha[s] + alpha[s-1] (+ alpha[s-2]
    # when ext[s] != blank and ext[s] != ext[s-2])
    ext_prev2 = jnp.concatenate(
        [jnp.full(ext.shape[:-1] + (2,), -1, ext.dtype), ext[..., :-2]], axis=-1
    )
    allow_skip = (ext != blank) & (ext != ext_prev2)  # [B, U]

    def emit(lp_t):  # gather per-state emission log-probs, [B, U]
        return jnp.take_along_axis(lp_t, ext, axis=-1)

    alpha0 = jnp.full((b, u), NEG_INF)
    alpha0 = alpha0.at[:, 0].set(emit(log_probs[:, 0])[:, 0])
    alpha0 = alpha0.at[:, 1].set(
        jnp.where(label_lengths > 0, emit(log_probs[:, 0])[:, 1], NEG_INF)
    )

    def step(alpha, lp_t):
        stay = alpha
        adv1 = jnp.concatenate([jnp.full((b, 1), NEG_INF), alpha[:, :-1]], axis=-1)
        adv2 = jnp.concatenate([jnp.full((b, 2), NEG_INF), alpha[:, :-2]], axis=-1)
        adv2 = jnp.where(allow_skip, adv2, NEG_INF)
        merged = jnp.logaddexp(jnp.logaddexp(stay, adv1), adv2)
        return merged + emit(lp_t), None

    def scan_step(carry, inp):
        alpha, t_idx = carry
        lp_t = inp
        new_alpha, _ = step(alpha, lp_t)
        # freeze alpha past each sample's frame_length
        active = (t_idx < frame_lengths)[:, None]
        alpha = jnp.where(active, new_alpha, alpha)
        return (alpha, t_idx + 1), None

    (alpha, _), _ = jax.lax.scan(scan_step, (alpha0, jnp.int32(1)), log_probs[:, 1:].swapaxes(0, 1))

    # final states: last blank (2*len) and last label (2*len - 1)
    idx_last = 2 * label_lengths
    idx_prev = jnp.maximum(2 * label_lengths - 1, 0)
    ll = jnp.logaddexp(
        jnp.take_along_axis(alpha, idx_last[:, None], axis=-1)[:, 0],
        jnp.where(
            label_lengths > 0,
            jnp.take_along_axis(alpha, idx_prev[:, None], axis=-1)[:, 0],
            NEG_INF,
        ),
    )
    return -(ll.mean())
