"""LTW1 — the tensor-bundle interchange format between python and rust.

A deliberately boring little-endian binary format (no pickle, no numpy
headers) so the rust side (`rust/src/weights.rs`) can read it with nothing
but std::io:

    b"LTW1"
    u32  n_tensors
    repeat n_tensors:
        u32  name_len,  name (utf-8)
        u8   dtype      (0 = f32, 1 = i32)
        u32  ndim
        u32  dims[ndim]
        raw  data       (little-endian, C order)

Used for initial model parameters (aot.py), checkpoints written back by the
rust trainer, and test fixtures.
"""

from __future__ import annotations

import struct
from typing import Iterable

import numpy as np

MAGIC = b"LTW1"
_DTYPES = {0: np.float32, 1: np.int32}
_DTYPE_IDS = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_ltw(path: str, tensors: Iterable[tuple[str, np.ndarray]]) -> None:
    tensors = list(tensors)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPE_IDS:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _DTYPE_IDS[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read_ltw(path: str) -> list[tuple[str, np.ndarray]]:
    out = []
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: not an LTW1 file")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (dt,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.dtype(_DTYPES[dt]).newbyteorder("<")
            count = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(count * dtype.itemsize), dtype=dtype)
            out.append((name, arr.reshape(dims).astype(_DTYPES[dt])))
    return out
