"""LSH attention (Reformer, Kitaev et al. 2020) — the paper's main baseline.

Faithful-in-structure jax implementation used by the convergence experiment
(Figure 2) and the training-evolution curves (Figure 5): shared-QK attention
where each position only attends to positions that hash to the same LSH
bucket (angular LSH via random rotations), bucketed into sorted chunks with
look-back of one chunk, over ``n_rounds`` independent hash rounds.

Implementation note (documented in DESIGN.md): the candidate set is realized
as a dense N x N mask rather than gather/scatter chunk kernels. For the
sequence lengths where we *train* lsh models (N <= 784) this is exact and
simple; the speed characteristics of chunked LSH are measured by the rust
`attention::lsh` engine, which implements the real sort-chunk-attend
pipeline. What this module must get right is the *selection noise* of
hashing, which is what Figure 2/5 attribute lsh's convergence gap to.

The random rotations are sampled once at model init and kept fixed
(a simplification over per-step re-hashing; Reformer re-samples per batch —
fixed rotations retain the characteristic bucket-boundary noise while
keeping the lowered artifact deterministic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e9


def make_rotations(key, n_rounds: int, d: int, n_buckets: int) -> jax.Array:
    """Random rotation bank: [rounds, D, n_buckets // 2]."""
    assert n_buckets % 2 == 0, "angular LSH needs an even bucket count"
    return jax.random.normal(key, (n_rounds, d, n_buckets // 2), jnp.float32)


def _bucket_ids(x: jax.Array, rot: jax.Array) -> jax.Array:
    """Angular LSH: argmax over [xR; -xR]. x [.., N, D], rot [D, B/2] -> [.., N]."""
    proj = jnp.einsum("...nd,db->...nb", x, rot)
    proj = jnp.concatenate([proj, -proj], axis=-1)
    return jnp.argmax(proj, axis=-1)


def _chunk_mask(buckets: jax.Array, chunk: int) -> jax.Array:
    """Candidate mask [.., N, N]: same or adjacent sorted chunk.

    Positions are sorted by (bucket, position) — Reformer's stable bucket
    sort — cut into chunks of `chunk`, and i may attend to j iff j's chunk
    is i's chunk or the one before it.
    """
    n = buckets.shape[-1]
    pos = jnp.arange(n)
    # stable sort key: bucket * N + position
    order = jnp.argsort(buckets * n + pos, axis=-1)  # [.., N] sorted->orig
    ranks = jnp.argsort(order, axis=-1)  # orig -> sorted rank
    chunk_id = ranks // chunk  # [.., N]
    ci = chunk_id[..., :, None]
    cj = chunk_id[..., None, :]
    return (cj == ci) | (cj == ci - 1)


@functools.partial(jax.jit, static_argnames=("chunk", "causal"))
def lsh_attention(
    qk: jax.Array,  # [B, H, N, D] shared queries/keys (Reformer ties them)
    v: jax.Array,  # [B, H, N, M]
    rotations: jax.Array,  # [rounds, D, n_buckets/2]
    chunk: int = 32,
    causal: bool = True,
) -> jax.Array:
    """Multi-round LSH attention; rounds are merged by their softmax mass."""
    b, h, n, d = qk.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    # Reformer normalizes keys; with shared QK, normalize the key role only.
    k = qk / (jnp.linalg.norm(qk, axis=-1, keepdims=True) + 1e-6)
    logits = jnp.einsum("bhnd,bhmd->bhnm", qk, k) * scale  # [B,H,N,N]

    pos = jnp.arange(n)
    base = jnp.ones((n, n), bool)
    if causal:
        base = pos[None, :] <= pos[:, None]
    # shared-QK models exclude self-attention except as a last resort; we
    # down-weight the diagonal like the reference implementation.
    diag = jnp.eye(n, dtype=bool)

    outs = []
    weights = []
    for r in range(rotations.shape[0]):
        buckets = _bucket_ids(k, rotations[r])  # [B,H,N]
        cand = _chunk_mask(buckets, chunk) & base[None, None]
        lg = jnp.where(cand, logits, NEG)
        lg = jnp.where(diag[None, None], lg - 1e5, lg)  # self only if alone
        mx = lg.max(-1, keepdims=True)
        ex = jnp.exp(lg - mx)
        denom = ex.sum(-1, keepdims=True)
        outs.append(jnp.einsum("bhnm,bhme->bhne", ex / (denom + 1e-9), v))
        # round weight: total un-normalized mass (higher = better bucket hit)
        weights.append((mx[..., 0] + jnp.log(denom[..., 0] + 1e-9)))
    out = jnp.stack(outs)  # [R,B,H,N,M]
    w = jax.nn.softmax(jnp.stack(weights), axis=0)  # [R,B,H,N]
    return (out * w[..., None]).sum(0)
