"""L2: the transformer language model, built on the L1 Pallas kernels.

One model definition with a pluggable attention family — exactly the
paper's experimental setup:

  "linear"  — causal linear attention (Pallas constant-memory kernel)
  "softmax" — full softmax attention  (Pallas baseline kernel)
  "lsh"     — Reformer-style LSH attention (lsh_attention.py)

plus the two inference formulations the paper contrasts:

  forward(...)        — parallel training/eval pass over a full sequence
  prefill(...)        — parallel pass that *also* returns the per-layer RNN
                        states (S, Z) at the end of the prompt (eqs 10-11)
  decode_step(...)    — eqs 16-20: one autoregressive step in O(1) time and
                        memory, carrying (s, z)
  decode_step_kv(...) — "stateful-softmax" baseline (supplementary C.1):
                        softmax decode with a KV cache, O(N) per step

Parameters are a flat {name: array} dict; `param_names(cfg)` fixes the
canonical ordering that aot.py records in the manifest and the rust side
reuses. Everything is f32 and shape-static so it lowers to clean HLO.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import lsh_attention as lsh_mod
from .kernels import (
    causal_linear_attention_cm,
    linear_attention,
    softmax_attention,
)
from .kernels.feature_maps import elu_plus_one

EPS = 1e-6


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 12
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    max_len: int = 128
    d_ff: int = 512
    attention: str = "linear"  # linear | softmax | lsh
    chunk: int = 16  # causal linear attention chunk size
    lsh_rounds: int = 1
    lsh_buckets: int = 16
    lsh_chunk: int = 32
    causal: bool = True  # False => encoder (speech/CTC) stack

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_names(cfg: ModelConfig) -> list[str]:
    """Canonical parameter order — the contract with the rust trainer."""
    names = ["embed.tok", "embed.pos"]
    for i in range(cfg.n_layers):
        p = f"layer{i}"
        names += [
            f"{p}.ln1.g",
            f"{p}.ln1.b",
            f"{p}.attn.wq",
            f"{p}.attn.wk",
            f"{p}.attn.wv",
            f"{p}.attn.wo",
            f"{p}.ln2.g",
            f"{p}.ln2.b",
            f"{p}.ff.w1",
            f"{p}.ff.b1",
            f"{p}.ff.w2",
            f"{p}.ff.b2",
        ]
    names += ["final_ln.g", "final_ln.b", "head.w", "head.b"]
    if cfg.attention == "lsh":
        names.append("lsh.rotations")
    return names


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Scaled-normal init (numpy RNG: artifact builds stay deterministic)."""
    rng = np.random.default_rng(seed)
    e, h, dh, ff, v = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff, cfg.vocab

    def dense(shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)

    p: dict[str, jnp.ndarray] = {
        "embed.tok": dense((v, e), 0.02),
        "embed.pos": dense((cfg.max_len, e), 0.02),
        "final_ln.g": jnp.ones((e,), jnp.float32),
        "final_ln.b": jnp.zeros((e,), jnp.float32),
        "head.w": dense((e, v)),
        "head.b": jnp.zeros((v,), jnp.float32),
    }
    for i in range(cfg.n_layers):
        pre = f"layer{i}"
        p[f"{pre}.ln1.g"] = jnp.ones((e,), jnp.float32)
        p[f"{pre}.ln1.b"] = jnp.zeros((e,), jnp.float32)
        p[f"{pre}.attn.wq"] = dense((e, e))
        p[f"{pre}.attn.wk"] = dense((e, e))
        p[f"{pre}.attn.wv"] = dense((e, e))
        p[f"{pre}.attn.wo"] = dense((e, e))
        p[f"{pre}.ln2.g"] = jnp.ones((e,), jnp.float32)
        p[f"{pre}.ln2.b"] = jnp.zeros((e,), jnp.float32)
        p[f"{pre}.ff.w1"] = dense((e, ff))
        p[f"{pre}.ff.b1"] = jnp.zeros((ff,), jnp.float32)
        p[f"{pre}.ff.w2"] = dense((ff, e))
        p[f"{pre}.ff.b2"] = jnp.zeros((e,), jnp.float32)
    if cfg.attention == "lsh":
        key = jax.random.PRNGKey(seed)
        p["lsh.rotations"] = lsh_mod.make_rotations(
            key, cfg.lsh_rounds, cfg.d_head, cfg.lsh_buckets
        )
    return p


def params_to_list(cfg: ModelConfig, params: dict) -> list[jnp.ndarray]:
    return [params[n] for n in param_names(cfg)]


def params_from_list(cfg: ModelConfig, plist) -> dict[str, jnp.ndarray]:
    return dict(zip(param_names(cfg), plist))


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def layer_norm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _split_heads(x, n_heads):  # [B,N,E] -> [B,H,N,Dh]
    b, n, e = x.shape
    return x.reshape(b, n, n_heads, e // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):  # [B,H,N,Dh] -> [B,N,E]
    b, h, n, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * d)


def _attention(cfg: ModelConfig, params, pre, x):
    """Multi-head attention of the configured family over [B, N, E]."""
    h = cfg.n_heads
    q = _split_heads(x @ params[f"{pre}.attn.wq"], h)
    v = _split_heads(x @ params[f"{pre}.attn.wv"], h)
    if cfg.attention == "lsh":
        # Reformer shares queries and keys
        out = lsh_mod.lsh_attention(
            q, v, params["lsh.rotations"], chunk=cfg.lsh_chunk, causal=cfg.causal
        )
    else:
        k = _split_heads(x @ params[f"{pre}.attn.wk"], h)
        if cfg.attention == "linear":
            if cfg.causal:
                out = causal_linear_attention_cm(q, k, v, chunk=cfg.chunk)
            else:
                out = linear_attention(q, k, v)
        elif cfg.attention == "softmax":
            out = softmax_attention(q, k, v, causal=cfg.causal)
        else:
            raise ValueError(f"unknown attention {cfg.attention!r}")
    return _merge_heads(out) @ params[f"{pre}.attn.wo"]


def _block(cfg, params, pre, x):
    """Pre-norm transformer block (eq. 1 with the now-standard norm order)."""
    x = x + _attention(cfg, params, pre, layer_norm(x, params[f"{pre}.ln1.g"], params[f"{pre}.ln1.b"]))
    hdd = layer_norm(x, params[f"{pre}.ln2.g"], params[f"{pre}.ln2.b"])
    hdd = jax.nn.gelu(hdd @ params[f"{pre}.ff.w1"] + params[f"{pre}.ff.b1"])
    return x + hdd @ params[f"{pre}.ff.w2"] + params[f"{pre}.ff.b2"]


# ---------------------------------------------------------------------------
# full-sequence forward (training / teacher-forced eval)
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """Causal LM logits: tokens [B, N] int32 -> [B, N, vocab]."""
    b, n = tokens.shape
    x = params["embed.tok"][tokens] + params["embed.pos"][:n][None]
    for i in range(cfg.n_layers):
        x = _block(cfg, params, f"layer{i}", x)
    x = layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    return x @ params["head.w"] + params["head.b"]


def encode(cfg: ModelConfig, params: dict, feats: jax.Array, in_proj: jax.Array) -> jax.Array:
    """Non-causal encoder for CTC speech: feats [B, T, F] -> [B, T, vocab]."""
    b, t, _ = feats.shape
    x = feats @ in_proj + params["embed.pos"][:t][None]
    for i in range(cfg.n_layers):
        x = _block(cfg, params, f"layer{i}", x)
    x = layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    return x @ params["head.w"] + params["head.b"]


# ---------------------------------------------------------------------------
# recurrent decode (section 3.4: transformers are RNNs)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int):
    """Zero RNN state: s [L,B,H,Dh,Dh] (eq. 16), z [L,B,H,Dh] (eq. 17)."""
    l, b, h, d = cfg.n_layers, batch, cfg.n_heads, cfg.d_head
    return (
        jnp.zeros((l, b, h, d, d), jnp.float32),
        jnp.zeros((l, b, h, d), jnp.float32),
    )


def decode_step(cfg: ModelConfig, params, token, pos, s, z):
    """One RNN step (eqs 18-20). token [B] int32, pos [B] int32.

    Positions are per-slot so the rust coordinator can continuously batch
    requests that are at different depths of their sequences.
    Returns (logits [B, vocab], s', z'). Cost is independent of pos — the
    paper's O(1)-per-token claim lives here.
    """
    b = token.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    x = params["embed.tok"][token] + params["embed.pos"][pos]  # [B, E]
    new_s, new_z = [], []
    for i in range(cfg.n_layers):
        pre = f"layer{i}"
        xi = layer_norm(x, params[f"{pre}.ln1.g"], params[f"{pre}.ln1.b"])
        q = elu_plus_one((xi @ params[f"{pre}.attn.wq"]).reshape(b, h, dh))
        k = elu_plus_one((xi @ params[f"{pre}.attn.wk"]).reshape(b, h, dh))
        v = (xi @ params[f"{pre}.attn.wv"]).reshape(b, h, dh)
        si = s[i] + k[..., :, None] * v[..., None, :]  # eq. 18
        zi = z[i] + k  # eq. 19
        num = jnp.einsum("bhd,bhdm->bhm", q, si)
        den = jnp.einsum("bhd,bhd->bh", q, zi)[..., None] + EPS
        attn = (num / den).reshape(b, h * dh) @ params[f"{pre}.attn.wo"]
        x = x + attn
        xf = layer_norm(x, params[f"{pre}.ln2.g"], params[f"{pre}.ln2.b"])
        xf = jax.nn.gelu(xf @ params[f"{pre}.ff.w1"] + params[f"{pre}.ff.b1"])
        x = x + xf @ params[f"{pre}.ff.w2"] + params[f"{pre}.ff.b2"]
        new_s.append(si)
        new_z.append(zi)
    x = layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    logits = x @ params["head.w"] + params["head.b"]
    return logits, jnp.stack(new_s), jnp.stack(new_z)


def prefill(cfg: ModelConfig, params, tokens):
    """Parallel prompt ingestion: full forward + final (S, Z) per layer.

    Returns (logits [B, N, vocab], s, z) where (s, z) equal the state
    decode_step would have reached after consuming `tokens` one by one —
    tested in test_model.py::test_prefill_decode_equivalence.
    """
    b, n = tokens.shape
    h = cfg.n_heads
    x = params["embed.tok"][tokens] + params["embed.pos"][:n][None]
    ss, zs = [], []
    for i in range(cfg.n_layers):
        pre = f"layer{i}"
        xi = layer_norm(x, params[f"{pre}.ln1.g"], params[f"{pre}.ln1.b"])
        q = _split_heads(xi @ params[f"{pre}.attn.wq"], h)
        k = _split_heads(xi @ params[f"{pre}.attn.wk"], h)
        v = _split_heads(xi @ params[f"{pre}.attn.wv"], h)
        out = causal_linear_attention_cm(q, k, v, chunk=cfg.chunk)
        km = elu_plus_one(k)
        ss.append(jnp.einsum("bhnd,bhnm->bhdm", km, v))  # S_N  (eq. 10)
        zs.append(km.sum(axis=2))  # Z_N  (eq. 11)
        x = x + _merge_heads(out) @ params[f"{pre}.attn.wo"]
        xf = layer_norm(x, params[f"{pre}.ln2.g"], params[f"{pre}.ln2.b"])
        xf = jax.nn.gelu(xf @ params[f"{pre}.ff.w1"] + params[f"{pre}.ff.b1"])
        x = x + xf @ params[f"{pre}.ff.w2"] + params[f"{pre}.ff.b2"]
    x = layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    logits = x @ params["head.w"] + params["head.b"]
    return logits, jnp.stack(ss), jnp.stack(zs)


# ---------------------------------------------------------------------------
# stateful-softmax baseline (supplementary C.1): KV-cache decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int):
    l, b, h, n, d = cfg.n_layers, batch, cfg.n_heads, cfg.max_len, cfg.d_head
    return (
        jnp.zeros((l, b, h, n, d), jnp.float32),
        jnp.zeros((l, b, h, n, d), jnp.float32),
    )


def decode_step_kv(cfg: ModelConfig, params, token, pos, k_cache, v_cache):
    """Softmax decode with cached keys/values. O(pos) work per step.

    token [B] int32, pos [B] int32 (per-slot, like decode_step). The
    paper's supplementary shows this 'recurrent view of softmax' is much
    faster than re-running the full forward, but still scales with the
    sequence — the contrast that makes Table 4 interesting.
    """
    b = token.shape[0]
    h, dh, nmax = cfg.n_heads, cfg.d_head, cfg.max_len
    x = params["embed.tok"][token] + params["embed.pos"][pos]  # [B, E]
    positions = jnp.arange(nmax)[None, :]  # [1, Nmax]
    valid = (positions <= pos[:, None])[:, None, :]  # [B, 1, Nmax]
    onehot = (positions == pos[:, None]).astype(jnp.float32)  # [B, Nmax]
    oh = onehot[:, None, :, None]  # [B, 1, Nmax, 1] broadcast over heads/dim
    new_kc, new_vc = [], []
    for i in range(cfg.n_layers):
        pre = f"layer{i}"
        xi = layer_norm(x, params[f"{pre}.ln1.g"], params[f"{pre}.ln1.b"])
        q = (xi @ params[f"{pre}.attn.wq"]).reshape(b, h, dh)
        k = (xi @ params[f"{pre}.attn.wk"]).reshape(b, h, dh)
        v = (xi @ params[f"{pre}.attn.wv"]).reshape(b, h, dh)
        # per-row scatter at each slot's own position (one-hot blend)
        kc = k_cache[i] * (1.0 - oh) + k[:, :, None, :] * oh
        vc = v_cache[i] * (1.0 - oh) + v[:, :, None, :] * oh
        logits = jnp.einsum("bhd,bhnd->bhn", q, kc) / jnp.sqrt(jnp.float32(dh))
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhn,bhnd->bhd", w, vc).reshape(b, h * dh)
        x = x + attn @ params[f"{pre}.attn.wo"]
        xf = layer_norm(x, params[f"{pre}.ln2.g"], params[f"{pre}.ln2.b"])
        xf = jax.nn.gelu(xf @ params[f"{pre}.ff.w1"] + params[f"{pre}.ff.b1"])
        x = x + xf @ params[f"{pre}.ff.w2"] + params[f"{pre}.ff.b2"]
        new_kc.append(kc)
        new_vc.append(vc)
    x = layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    logits = x @ params["head.w"] + params["head.b"]
    return logits, jnp.stack(new_kc), jnp.stack(new_vc)
