"""AOT export: lower every model function to HLO text + write weights/manifest.

This is the single build-time entry point (`make artifacts`). It produces,
under artifacts/:

  <task>_<variant>_train.hlo.txt   — one optimizer step (fwd+bwd+RAdam/Adam)
  <task>_<variant>_eval.hlo.txt    — scalar eval loss (teacher-forced)
  <task>_fwd_*.hlo.txt             — full posteriors where rust needs them
  <task>_decode_linear_b<B>.hlo.txt— eqs 16-20 RNN decode step, batch B
  <task>_decode_kv_b<B>.hlo.txt    — stateful-softmax KV-cache decode step
  <task>_prefill_b1.hlo.txt        — prompt ingestion -> (logits, S, Z)
  <task>_<variant>_init.ltw        — initial parameters (LTW1 bundle)
  manifest.json                    — artifact/param/shape registry for rust

Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects jax>=0.5
serialized protos (64-bit instruction ids); the text parser reassigns ids.
See /opt/xla-example/README.md.

Conventions the rust side relies on (rust/src/runtime/bundle.rs):
  * flat positional inputs, named in the manifest as
    "param:<name>", "opt_m:<name>", "opt_v:<name>", "opt_step", "lr",
    "in:<field>", "state:s", "state:z", "cache:k", "cache:v"
  * train outputs: ("loss", params..., m..., v..., "opt_step")
  * every tensor is f32 except token/index inputs which are i32
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import losses, model as model_mod, models_speech as speech_mod
from .ltw import write_ltw
from .model import ModelConfig
from .optimizers import OptState, UPDATES, clip_by_global_norm

F32 = jnp.float32
I32 = jnp.int32


# ---------------------------------------------------------------------------
# task registry
# ---------------------------------------------------------------------------


def _lm_cfg(attention, **kw):
    return ModelConfig(attention=attention, **kw)


COPY_KW = dict(vocab=13, d_model=128, n_heads=4, n_layers=4, max_len=128, d_ff=512, chunk=16)
MNIST_KW = dict(vocab=256, d_model=128, n_heads=4, n_layers=4, max_len=784, d_ff=512, chunk=16, lsh_chunk=32, lsh_buckets=32)
CIFAR_KW = dict(vocab=256, d_model=128, n_heads=4, n_layers=4, max_len=3072, d_ff=512, chunk=16)
SPEECH_KW = dict(vocab=41, d_model=128, n_heads=4, n_layers=4, max_len=256, d_ff=512, chunk=16, causal=False)

TASKS = {
    "copy": dict(kw=COPY_KW, batch=32, variants=["linear", "softmax", "lsh"], kind="lm"),
    "mnist": dict(kw=MNIST_KW, batch=8, variants=["linear", "softmax", "lsh"], kind="lm"),
    "cifar": dict(kw=CIFAR_KW, batch=2, variants=["linear", "softmax"], kind="lm"),
    "speech": dict(
        kw=SPEECH_KW,
        batch=8,
        variants=["linear", "softmax", "bilstm"],
        kind="ctc",
        n_mels=40,
        max_labels=48,
    ),
}

DECODE_BATCHES = {"copy": [1], "mnist": [1, 32], "cifar": [1, 16]}
PREFILL_LEN = {"mnist": 384, "cifar": 1024}


# ---------------------------------------------------------------------------
# HLO text lowering (the aot_recipe / xla-example path)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": "i32" if x.dtype == jnp.int32 else "f32"}


def lower_artifact(out_dir, name, fn, named_inputs, output_names, manifest, model_key):
    """jit-lower fn(*inputs), dump HLO text, record manifest entry."""
    specs = [jax.ShapeDtypeStruct(x.shape, x.dtype) for _, x in named_inputs]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # output specs via eval_shape (no execution)
    out_shapes = jax.eval_shape(fn, *specs)
    flat_out = jax.tree_util.tree_leaves(out_shapes)
    assert len(flat_out) == len(output_names), (name, len(flat_out), len(output_names))
    manifest["artifacts"][name] = {
        "file": fname,
        "model": model_key,
        "inputs": [{"name": n, **spec_of(x)} for n, x in named_inputs],
        "outputs": [{"name": n, **spec_of(x)} for n, x in zip(output_names, flat_out)],
    }
    print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB, {len(named_inputs)} inputs)")


# ---------------------------------------------------------------------------
# generic train/eval step builders
# ---------------------------------------------------------------------------


def make_train_step(names, loss_fn, opt_name, batch_template, clip_norm=1.0):
    """Flat-signature train step for a parameter list in `names` order."""
    p_count = len(names)

    def train_step(*args):
        params = list(args[:p_count])
        m = list(args[p_count : 2 * p_count])
        v = list(args[2 * p_count : 3 * p_count])
        step = args[3 * p_count]
        lr = args[3 * p_count + 1]
        batch = args[3 * p_count + 2 :]

        def lf(plist):
            return loss_fn(dict(zip(names, plist)), *batch)

        loss, grads = jax.value_and_grad(lf)(params)
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        new_p, st = UPDATES[opt_name](params, grads, OptState(m, v, step), lr)
        return (loss, *new_p, *st.m, *st.v, st.step)

    return train_step


def train_io_names(names, batch_fields):
    inputs = (
        [f"param:{n}" for n in names]
        + [f"opt_m:{n}" for n in names]
        + [f"opt_v:{n}" for n in names]
        + ["opt_step", "lr"]
        + [f"in:{f}" for f in batch_fields]
    )
    outputs = (
        ["loss"]
        + [f"param:{n}" for n in names]
        + [f"opt_m:{n}" for n in names]
        + [f"opt_v:{n}" for n in names]
        + ["opt_step"]
    )
    return inputs, outputs


def zeros_like_params(params):
    return [jnp.zeros_like(p) for p in params]


# ---------------------------------------------------------------------------
# per-task emitters
# ---------------------------------------------------------------------------


def emit_lm_task(task, spec, out_dir, manifest):
    batch = spec["batch"]
    for variant in spec["variants"]:
        cfg = _lm_cfg(variant, **spec["kw"])
        key = f"{task}_{variant}"
        names = model_mod.param_names(cfg)
        params = model_mod.init_params(cfg, seed=hash(key) % 2**31)
        plist = model_mod.params_to_list(cfg, params)

        write_ltw(
            os.path.join(out_dir, f"{key}_init.ltw"),
            [(n, np.asarray(a)) for n, a in zip(names, plist)],
        )
        manifest["models"][key] = {
            "task": task,
            "attention": variant,
            "config": asdict(cfg),
            "params": names,
            "param_shapes": {n: list(params[n].shape) for n in names},
            "weights": f"{key}_init.ltw",
        }

        n = cfg.max_len

        def lm_loss(pd, inputs, targets, mask):
            logits = model_mod.forward(cfg, pd, inputs)
            return losses.cross_entropy(logits, targets, mask)

        tok = jnp.zeros((batch, n), I32)
        msk = jnp.ones((batch, n), F32)
        sc = jnp.zeros((), F32)
        batch_inputs = [("in:inputs", tok), ("in:targets", tok), ("in:mask", msk)]

        in_names, out_names = train_io_names(names, ["inputs", "targets", "mask"])
        named_inputs = (
            [(f"param:{nm}", p) for nm, p in zip(names, plist)]
            + [(f"opt_m:{nm}", p) for nm, p in zip(names, plist)]
            + [(f"opt_v:{nm}", p) for nm, p in zip(names, plist)]
            + [("opt_step", sc), ("lr", sc)]
            + batch_inputs
        )
        lower_artifact(
            out_dir,
            f"{key}_train",
            make_train_step(names, lm_loss, "radam", None),
            named_inputs,
            out_names,
            manifest,
            key,
        )

        # eval: scalar mean CE (rust converts to bits/dim)
        def eval_loss(*args):
            pd = dict(zip(names, args[: len(names)]))
            inputs, targets, mask = args[len(names) :]
            return (losses.cross_entropy(model_mod.forward(cfg, pd, inputs), targets, mask),)

        lower_artifact(
            out_dir,
            f"{key}_eval",
            eval_loss,
            [(f"param:{nm}", p) for nm, p in zip(names, plist)] + batch_inputs,
            ["loss"],
            manifest,
            key,
        )

    # decode-step artifacts exist for the linear (RNN) and softmax (KV) models
    cfg_lin = _lm_cfg("linear", **spec["kw"])
    names_lin = model_mod.param_names(cfg_lin)
    params_lin = model_mod.init_params(cfg_lin)
    plist_lin = model_mod.params_to_list(cfg_lin, params_lin)
    cfg_sm = _lm_cfg("softmax", **spec["kw"])
    names_sm = model_mod.param_names(cfg_sm)
    plist_sm = model_mod.params_to_list(cfg_sm, model_mod.init_params(cfg_sm))

    for b in DECODE_BATCHES.get(task, []):
        s0, z0 = model_mod.init_decode_state(cfg_lin, b)
        tok = jnp.zeros((b,), I32)
        pos = jnp.zeros((b,), I32)  # per-slot positions (continuous batching)

        def dec(*args):
            pd = dict(zip(names_lin, args[: len(names_lin)]))
            token, p, s, z = args[len(names_lin) :]
            return model_mod.decode_step(cfg_lin, pd, token, p, s, z)

        lower_artifact(
            out_dir,
            f"{task}_decode_linear_b{b}",
            dec,
            [(f"param:{nm}", p) for nm, p in zip(names_lin, plist_lin)]
            + [("in:token", tok), ("in:pos", pos), ("state:s", s0), ("state:z", z0)],
            ["out:logits", "state:s", "state:z"],
            manifest,
            f"{task}_linear",
        )

        kc0, vc0 = model_mod.init_kv_cache(cfg_sm, b)

        def dec_kv(*args):
            pd = dict(zip(names_sm, args[: len(names_sm)]))
            token, p, kc, vc = args[len(names_sm) :]
            return model_mod.decode_step_kv(cfg_sm, pd, token, p, kc, vc)

        lower_artifact(
            out_dir,
            f"{task}_decode_kv_b{b}",
            dec_kv,
            [(f"param:{nm}", p) for nm, p in zip(names_sm, plist_sm)]
            + [("in:token", tok), ("in:pos", pos), ("cache:k", kc0), ("cache:v", vc0)],
            ["out:logits", "cache:k", "cache:v"],
            manifest,
            f"{task}_softmax",
        )

    if task in PREFILL_LEN:
        plen = PREFILL_LEN[task]
        tok = jnp.zeros((1, plen), I32)

        def pre(*args):
            pd = dict(zip(names_lin, args[: len(names_lin)]))
            return model_mod.prefill(cfg_lin, pd, args[-1])

        lower_artifact(
            out_dir,
            f"{task}_prefill_b1",
            pre,
            [(f"param:{nm}", p) for nm, p in zip(names_lin, plist_lin)]
            + [("in:tokens", tok)],
            ["out:logits", "state:s", "state:z"],
            manifest,
            f"{task}_linear",
        )


def emit_ctc_task(task, spec, out_dir, manifest):
    batch, n_mels, max_s = spec["batch"], spec["n_mels"], spec["max_labels"]
    t = spec["kw"]["max_len"]
    feats = jnp.zeros((batch, t, n_mels), F32)
    flen = jnp.zeros((batch,), I32)
    labels = jnp.zeros((batch, max_s), I32)
    llen = jnp.zeros((batch,), I32)
    sc = jnp.zeros((), F32)
    batch_inputs = [
        ("in:feats", feats),
        ("in:frame_len", flen),
        ("in:labels", labels),
        ("in:label_len", llen),
    ]
    batch_fields = ["feats", "frame_len", "labels", "label_len"]

    for variant in spec["variants"]:
        key = f"{task}_{variant}"
        if variant == "bilstm":
            lcfg = speech_mod.LstmConfig(n_mels=n_mels, hidden=128, n_layers=3, vocab=spec["kw"]["vocab"])
            names = speech_mod.lstm_param_names(lcfg)
            pd0 = speech_mod.init_lstm_params(lcfg)
            fwd = lambda pd, f: speech_mod.lstm_forward(lcfg, pd, f)
            opt = "adam"
            cfg_json = asdict(lcfg)
        else:
            cfg = _lm_cfg(variant, **spec["kw"])
            names = speech_mod.speech_param_names(cfg)
            pd0 = speech_mod.init_speech_params(cfg, n_mels)
            fwd = lambda pd, f, cfg=cfg: speech_mod.speech_forward(cfg, pd, f)
            opt = "radam"
            cfg_json = asdict(cfg)
        plist = [pd0[n] for n in names]

        write_ltw(
            os.path.join(out_dir, f"{key}_init.ltw"),
            [(n, np.asarray(a)) for n, a in zip(names, plist)],
        )
        manifest["models"][key] = {
            "task": task,
            "attention": variant,
            "config": cfg_json,
            "params": names,
            "param_shapes": {n: list(pd0[n].shape) for n in names},
            "weights": f"{key}_init.ltw",
        }

        def ctc_of(pd, feats, frame_len, labels, label_len, fwd=fwd):
            logp = fwd(pd, feats)
            return losses.ctc_loss(logp, frame_len, labels, label_len, blank=0)

        in_names, out_names = train_io_names(names, batch_fields)
        named_inputs = (
            [(f"param:{nm}", p) for nm, p in zip(names, plist)]
            + [(f"opt_m:{nm}", p) for nm, p in zip(names, plist)]
            + [(f"opt_v:{nm}", p) for nm, p in zip(names, plist)]
            + [("opt_step", sc), ("lr", sc)]
            + batch_inputs
        )
        lower_artifact(
            out_dir,
            f"{key}_train",
            make_train_step(names, ctc_of, opt, None),
            named_inputs,
            out_names,
            manifest,
            key,
        )

        def fwd_only(*args, fwd=fwd, names=names):
            pd = dict(zip(names, args[: len(names)]))
            return (fwd(pd, args[-1]),)

        lower_artifact(
            out_dir,
            f"{key}_fwd",
            fwd_only,
            [(f"param:{nm}", p) for nm, p in zip(names, plist)] + [("in:feats", feats)],
            ["out:logp"],
            manifest,
            key,
        )


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tasks", default="copy,mnist,cifar,speech")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # merge into an existing manifest so per-task incremental runs compose
    manifest = {"format": "hlo-text-v1", "models": {}, "artifacts": {}}
    mpath = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            prev = json.load(f)
        if prev.get("format") == manifest["format"]:
            manifest = prev
    for task in args.tasks.split(","):
        spec = TASKS[task]
        print(f"[aot] task {task}")
        if spec["kind"] == "lm":
            emit_lm_task(task, spec, args.out_dir, manifest)
        else:
            emit_ctc_task(task, spec, args.out_dir, manifest)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts, {len(manifest['models'])} models")


if __name__ == "__main__":
    main()
