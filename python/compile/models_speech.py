"""L2 speech models for the CTC experiment (paper section 4.3, Table 3).

Two encoder families over mel-filterbank frames [B, T, F]:

  * transformer encoder (non-causal) — reuses model.py blocks with the
    configured attention (linear / softmax / lsh), plus an input projection.
  * Bi-LSTM — the paper's recurrent baseline (3 layers in the paper),
    implemented from scratch with lax.scan.

Both emit frame-level log-posteriors over phonemes+blank for CTC.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_mod
from .model import ModelConfig, layer_norm


# ---------------------------------------------------------------------------
# transformer encoder
# ---------------------------------------------------------------------------


def speech_param_names(cfg: ModelConfig) -> list[str]:
    """Transformer-encoder params: input projection replaces the token embed."""
    names = ["in_proj.w", "in_proj.b"]
    names += [n for n in model_mod.param_names(cfg) if n != "embed.tok"]
    return names


def init_speech_params(cfg: ModelConfig, n_mels: int, seed: int = 0):
    rng = np.random.default_rng(seed + 1000)
    base = model_mod.init_params(cfg, seed)
    del base["embed.tok"]
    base["in_proj.w"] = jnp.asarray(
        rng.normal(0.0, 1.0 / np.sqrt(n_mels), size=(n_mels, cfg.d_model)), jnp.float32
    )
    base["in_proj.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return base


def speech_forward(cfg: ModelConfig, params: dict, feats: jax.Array) -> jax.Array:
    """feats [B, T, F] -> log-softmax phoneme posteriors [B, T, vocab]."""
    b, t, _ = feats.shape
    x = feats @ params["in_proj.w"] + params["in_proj.b"]
    x = x + params["embed.pos"][:t][None]
    for i in range(cfg.n_layers):
        x = model_mod._block(cfg, params, f"layer{i}", x)
    x = layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    logits = x @ params["head.w"] + params["head.b"]
    return jax.nn.log_softmax(logits, axis=-1)


# ---------------------------------------------------------------------------
# Bi-LSTM baseline
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LstmConfig:
    n_mels: int = 40
    hidden: int = 128
    n_layers: int = 3
    vocab: int = 41  # 40 phonemes + blank


def lstm_param_names(cfg: LstmConfig) -> list[str]:
    names = []
    for i in range(cfg.n_layers):
        for d in ("fwd", "bwd"):
            names += [f"lstm{i}.{d}.wx", f"lstm{i}.{d}.wh", f"lstm{i}.{d}.b"]
    names += ["head.w", "head.b"]
    return names


def init_lstm_params(cfg: LstmConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed + 2000)
    h = cfg.hidden
    p = {}
    for i in range(cfg.n_layers):
        d_in = cfg.n_mels if i == 0 else 2 * h
        for d in ("fwd", "bwd"):
            p[f"lstm{i}.{d}.wx"] = jnp.asarray(
                rng.normal(0, 1.0 / np.sqrt(d_in), (d_in, 4 * h)), jnp.float32
            )
            p[f"lstm{i}.{d}.wh"] = jnp.asarray(
                rng.normal(0, 1.0 / np.sqrt(h), (h, 4 * h)), jnp.float32
            )
            # forget-gate bias = 1 (standard LSTM trick)
            b = np.zeros(4 * h, np.float32)
            b[h : 2 * h] = 1.0
            p[f"lstm{i}.{d}.b"] = jnp.asarray(b)
    p["head.w"] = jnp.asarray(
        rng.normal(0, 1.0 / np.sqrt(2 * h), (2 * h, cfg.vocab)), jnp.float32
    )
    p["head.b"] = jnp.zeros((cfg.vocab,), jnp.float32)
    return p


def _lstm_scan(x, wx, wh, b, reverse: bool):
    """Single-direction LSTM over [B, T, D] -> [B, T, H]."""
    bsz = x.shape[0]
    h_dim = wh.shape[0]
    xs = x.swapaxes(0, 1)  # [T, B, D]

    def step(carry, xt):
        h, c = carry
        gates = xt @ wx + h @ wh + b
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        c = f * c + i * jnp.tanh(g)
        h = o * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((bsz, h_dim), x.dtype), jnp.zeros((bsz, h_dim), x.dtype))
    _, hs = jax.lax.scan(step, init, xs, reverse=reverse)
    return hs.swapaxes(0, 1)


def lstm_forward(cfg: LstmConfig, params: dict, feats: jax.Array) -> jax.Array:
    """Bi-LSTM encoder: feats [B, T, F] -> log posteriors [B, T, vocab]."""
    x = feats
    for i in range(cfg.n_layers):
        f = _lstm_scan(
            x, params[f"lstm{i}.fwd.wx"], params[f"lstm{i}.fwd.wh"], params[f"lstm{i}.fwd.b"], False
        )
        b = _lstm_scan(
            x, params[f"lstm{i}.bwd.wx"], params[f"lstm{i}.bwd.wh"], params[f"lstm{i}.bwd.b"], True
        )
        x = jnp.concatenate([f, b], axis=-1)
    logits = x @ params["head.w"] + params["head.b"]
    return jax.nn.log_softmax(logits, axis=-1)
