"""Build-time Python package: L1 Pallas kernels + L2 JAX models + AOT export.

Nothing in here runs on the request path — `make artifacts` lowers every
function to HLO text under artifacts/ and the rust binary takes over.
"""
