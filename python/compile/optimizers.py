"""Pure-jax optimizers: RAdam (the paper's optimizer) and Adam.

RAdam (Liu et al., 2019) rectifies Adam's early-training variance: until the
approximated SMA length rho_t exceeds the threshold, the step falls back to
(momentum-only) SGD; after that the usual Adam update is scaled by the
rectification term r_t. The paper trains every transformer with RAdam.

State layout is a pair of per-parameter trees (m, v) plus a scalar step
count — flattened in a fixed order by aot.py so the rust trainer can carry
the state as opaque literals between train_step executions.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    m: list  # first-moment EMAs, one per param leaf
    v: list  # second-moment EMAs
    step: jax.Array  # scalar f32 (kept float so every literal is f32)


def init_opt_state(params: list[jax.Array]) -> OptState:
    return OptState(
        m=[jnp.zeros_like(p) for p in params],
        v=[jnp.zeros_like(p) for p in params],
        step=jnp.zeros((), jnp.float32),
    )


def radam_update(
    params: list[jax.Array],
    grads: list[jax.Array],
    state: OptState,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[list[jax.Array], OptState]:
    """One RAdam step over a flat list of parameter leaves."""
    t = state.step + 1.0
    rho_inf = 2.0 / (1.0 - b2) - 1.0
    b2t = jnp.power(b2, t)
    b1t = jnp.power(b1, t)
    rho_t = rho_inf - 2.0 * t * b2t / (1.0 - b2t)

    rect = jnp.sqrt(
        jnp.clip(
            ((rho_t - 4.0) * (rho_t - 2.0) * rho_inf)
            / jnp.maximum((rho_inf - 4.0) * (rho_inf - 2.0) * rho_t, 1e-8),
            0.0,
        )
    )
    use_rect = rho_t > 5.0

    new_m, new_v, new_p = [], [], []
    for p, g, m, v in zip(params, grads, state.m, state.v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * (g * g)
        m_hat = m / (1.0 - b1t)
        v_hat = jnp.sqrt(v / (1.0 - b2t)) + eps
        step_rect = lr * rect * m_hat / v_hat
        step_sgd = lr * m_hat
        new_p.append(p - jnp.where(use_rect, step_rect, step_sgd))
        new_m.append(m)
        new_v.append(v)
    return new_p, OptState(new_m, new_v, t)


def adam_update(
    params: list[jax.Array],
    grads: list[jax.Array],
    state: OptState,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[list[jax.Array], OptState]:
    """Vanilla Adam, used by the Bi-LSTM baseline (paper section 4.3)."""
    t = state.step + 1.0
    b1t = jnp.power(b1, t)
    b2t = jnp.power(b2, t)
    new_m, new_v, new_p = [], [], []
    for p, g, m, v in zip(params, grads, state.m, state.v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * (g * g)
        m_hat = m / (1.0 - b1t)
        v_hat = v / (1.0 - b2t)
        new_p.append(p - lr * m_hat / (jnp.sqrt(v_hat) + eps))
        new_m.append(m)
        new_v.append(v)
    return new_p, OptState(new_m, new_v, t)


def clip_by_global_norm(grads: list[jax.Array], max_norm: float) -> list[jax.Array]:
    """Global-norm gradient clipping (stabilizes the lr=1e-3 copy task)."""
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-8))
    return [g * scale for g in grads]


UPDATES = {"radam": radam_update, "adam": adam_update}
