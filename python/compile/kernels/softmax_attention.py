"""Pallas kernel: standard softmax attention (paper eq. 2) — the baseline.

O(N^2 D) compute, O(N^2) memory per (batch, head): the kernel materializes
the full attention matrix, exactly the cost profile the paper's Figure 1
measures against. Grid is one program instance per fused (batch, head).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_weights(q, k, causal: bool):
    """Stable rowwise softmax of the (N, N) score matrix."""
    n, d = q.shape
    logits = jnp.dot(q, k.T) / jnp.sqrt(jnp.float32(d))  # (N, N)
    if causal:
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    logits = logits - logits.max(axis=-1, keepdims=True)
    w = jnp.exp(logits)
    return w / w.sum(axis=-1, keepdims=True)


def _make_softmax_kernel(causal: bool):
    def kernel(q_ref, k_ref, v_ref, o_ref):
        w = _softmax_weights(q_ref[0], k_ref[0], causal)
        o_ref[0] = jnp.dot(w, v_ref[0])

    return kernel


def _make_softmax_bwd_kernel(causal: bool):
    """Backward kernel; recomputes W (flash-style) instead of saving it.

    The O(N^2) attention matrix still has to exist transiently — that IS
    the softmax memory wall the paper measures in Figure 1.
    """

    def kernel(q_ref, k_ref, v_ref, g_ref, dq_ref, dk_ref, dv_ref):
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        g = g_ref[0]
        d = q.shape[-1]
        w = _softmax_weights(q, k, causal)  # (N, N)
        dv_ref[0] = jnp.dot(w.T, g)
        dw = jnp.dot(g, v.T)  # (N, N)
        dlogits = w * (dw - jnp.sum(dw * w, axis=-1, keepdims=True))
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        dq_ref[0] = jnp.dot(dlogits, k) * scale
        dk_ref[0] = jnp.dot(dlogits.T, q) * scale

    return kernel


def _bh_specs(n, d, m, count):
    return [
        pl.BlockSpec((1, n, dd), lambda i: (i, 0, 0)) for dd in ([d, d, m, m][:count])
    ]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _softmax_bh(q, k, v, causal):
    bh, n, d = q.shape
    m = v.shape[-1]
    return pl.pallas_call(
        _make_softmax_kernel(causal),
        grid=(bh,),
        in_specs=_bh_specs(n, d, m, 3),
        out_specs=pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, m), q.dtype),
        interpret=True,
    )(q, k, v)


def _softmax_bh_fwd(q, k, v, causal):
    return _softmax_bh(q, k, v, causal), (q, k, v)


def _softmax_bh_bwd(causal, res, g):
    q, k, v = res
    bh, n, d = q.shape
    m = v.shape[-1]
    dq, dk, dv = pl.pallas_call(
        _make_softmax_bwd_kernel(causal),
        grid=(bh,),
        in_specs=_bh_specs(n, d, m, 4),
        out_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), q.dtype),
            jax.ShapeDtypeStruct((bh, n, d), q.dtype),
            jax.ShapeDtypeStruct((bh, n, m), q.dtype),
        ],
        interpret=True,
    )(q, k, v, g)
    return dq, dk, dv


_softmax_bh.defvjp(_softmax_bh_fwd, _softmax_bh_bwd)


@functools.partial(jax.jit, static_argnames=("causal",))
def softmax_attention(q, k, v, causal=False):
    """Softmax attention over f32[B, H, N, D] / [B, H, N, M]."""
    b, h, n, d = q.shape
    m = v.shape[-1]
    out = _softmax_bh(
        q.reshape(b * h, n, d), k.reshape(b * h, n, d), v.reshape(b * h, n, m), causal
    )
    return out.reshape(b, h, n, m)
