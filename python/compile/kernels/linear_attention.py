"""Pallas kernel: non-causal linearized attention (paper eq. 4-6).

The associativity trick: instead of materializing phi(Q) phi(K)^T (N x N),
compute KV = phi(K)^T V (D x M) and Z = sum_j phi(K_j) (D) once, then every
query costs O(D*M). Total O(N*D*M) time, O(D*M) extra memory.

Kernel layout: inputs are reshaped to (B*H, N, *) outside the kernel and the
grid iterates over the fused batch*heads axis — one program instance per
(batch, head), the Pallas equivalent of the paper's CUDA block per (b, h).
Each instance stages its (N, D)/(N, M) slices HBM->VMEM via BlockSpec.

interpret=True everywhere: CPU PJRT cannot execute Mosaic custom-calls; the
kernel is still the real TPU schedule, just interpreted (see DESIGN.md
section Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .feature_maps import elu_plus_one

EPS = 1e-6


def _linear_attention_kernel(q_ref, k_ref, v_ref, o_ref):
    """One (batch, head) slice: q (1,N,D), k (1,N,D), v (1,N,M)."""
    q = q_ref[0]  # (N, D) in VMEM
    k = k_ref[0]
    v = v_ref[0]
    # KV-aggregation: phi(K)^T V is a (D, M) matmul — MXU-shaped on TPU.
    kv = jnp.dot(k.T, v)  # (D, M)
    z = jnp.sum(k, axis=0)  # (D,)
    num = jnp.dot(q, kv)  # (N, M)
    den = jnp.dot(q, z) + EPS  # (N,)
    o_ref[0] = num / den[:, None]


def _linear_attention_bwd_kernel(q_ref, k_ref, v_ref, g_ref, dq_ref, dk_ref, dv_ref):
    """Backward of the non-causal kernel, O(N) time / O(D*M) extra memory.

    With KV = phi(K)^T V, Z = sum_j phi(K_j), den_i = q_i.Z + eps,
    Gn_i = g_i/den_i, h_i = -(g_i.out_i)/den_i and A = sum_i q_i Gn_i^T:
        dq_i = KV Gn_i + h_i Z
        dk_j = A v_j + sum_i h_i q_i
        dv_j = A^T k_j
    — the same associativity trick as the forward, applied to the vjp.
    """
    q = q_ref[0]  # (N, D)
    k = k_ref[0]
    v = v_ref[0]
    g = g_ref[0]  # (N, M)
    kv = jnp.dot(k.T, v)  # (D, M)
    z = jnp.sum(k, axis=0)  # (D,)
    den = jnp.dot(q, z) + EPS  # (N,)
    num = jnp.dot(q, kv)  # (N, M)
    out = num / den[:, None]
    gn = g / den[:, None]
    hh = -jnp.sum(g * out, axis=-1) / den  # (N,)
    a = jnp.dot(q.T, gn)  # (D, M)
    u = jnp.dot(hh, q)  # (D,)
    dq_ref[0] = jnp.dot(gn, kv.T) + hh[:, None] * z[None, :]
    dk_ref[0] = jnp.dot(v, a.T) + u[None, :]
    dv_ref[0] = jnp.dot(k, a)


@jax.custom_vjp
def _linear_bh(q, k, v):
    bh, n, d = q.shape
    m = v.shape[-1]
    return pl.pallas_call(
        _linear_attention_kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, m), q.dtype),
        interpret=True,
    )(q, k, v)


def _linear_bh_fwd(q, k, v):
    return _linear_bh(q, k, v), (q, k, v)


def _linear_bh_bwd(res, g):
    q, k, v = res
    bh, n, d = q.shape
    m = v.shape[-1]
    dq, dk, dv = pl.pallas_call(
        _linear_attention_bwd_kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), q.dtype),
            jax.ShapeDtypeStruct((bh, n, d), q.dtype),
            jax.ShapeDtypeStruct((bh, n, m), q.dtype),
        ],
        interpret=True,
    )(q, k, v, g)
    return dq, dk, dv


_linear_bh.defvjp(_linear_bh_fwd, _linear_bh_bwd)


@functools.partial(jax.jit, static_argnames=("feature_map",))
def linear_attention(q, k, v, feature_map=True):
    """Non-causal linear attention over f32[B, H, N, D] / [B, H, N, M].

    If feature_map is True, applies phi(x) = elu(x)+1 to q and k first
    (paper eq. 7); pass False when the caller has already mapped them.
    """
    b, h, n, d = q.shape
    m = v.shape[-1]
    if feature_map:
        q = elu_plus_one(q)
        k = elu_plus_one(k)
    out = _linear_bh(
        q.reshape(b * h, n, d), k.reshape(b * h, n, d), v.reshape(b * h, n, m)
    )
    return out.reshape(b, h, n, m)
