"""Feature maps phi(.) for linearized attention (paper section 3.2).

The paper's choice is phi(x) = elu(x) + 1 (eq. 7): strictly positive, so the
similarity sim(q, k) = phi(q)^T phi(k) defines a valid attention kernel, and
smooth for x < 0 (unlike relu) so gradients never vanish on the negative side.

These are plain-jnp functions used both inside the Pallas kernels (they are
jnp-traceable elementwise ops) and by the L2 model code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["elu_plus_one", "relu_plus_eps", "squared_relu", "get_feature_map"]


def elu_plus_one(x: jax.Array) -> jax.Array:
    """phi(x) = elu(x) + 1  (paper eq. 7). Output is in (0, inf)."""
    return jax.nn.elu(x) + 1.0


def relu_plus_eps(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """phi(x) = relu(x) + eps. Ablation map; zero gradient for x < 0."""
    return jax.nn.relu(x) + eps


def squared_relu(x: jax.Array) -> jax.Array:
    """phi(x) = relu(x)^2. Ablation map with sharper selectivity."""
    r = jax.nn.relu(x)
    return r * r


_FEATURE_MAPS = {
    "elu+1": elu_plus_one,
    "relu+eps": relu_plus_eps,
    "relu2": squared_relu,
}


def get_feature_map(name: str):
    """Look up a feature map by name ('elu+1' is the paper's default)."""
    try:
        return _FEATURE_MAPS[name]
    except KeyError:
        raise ValueError(
            f"unknown feature map {name!r}; available: {sorted(_FEATURE_MAPS)}"
        ) from None
