"""Pallas kernels: causal linearized attention (paper section 3.3, Algorithm 1).

Three implementations of

    Vbar_i = phi(Q_i)^T S_i,   S_i = sum_{j<=i} phi(K_j) V_j^T
    den_i  = phi(Q_i)^T Z_i,   Z_i = sum_{j<=i} phi(K_j)
    out_i  = Vbar_i / (den_i + eps)

1. ``causal_linear_attention``        — scan kernel, the literal Algorithm 1
   loop: one sequential pass over N carrying (S, Z) in VMEM/registers. This
   is the paper's 200-line CUDA kernel transcribed to Pallas.
2. ``causal_linear_attention_chunked``— chunked kernel: the sequence is cut
   into chunks of T positions; the intra-chunk term is a masked (T x T)
   matmul (MXU-shaped on real TPU) and the inter-chunk term flows through
   the carried S. Mathematically identical, far better compute density.
3. ``causal_linear_attention_cm``     — chunked forward wrapped in a
   custom_vjp whose backward recomputes the cumulative sums instead of
   storing all N intermediate S_i — the paper's *constant-memory gradient*
   (section 3.3.1, eqs 13-15). Saves only (q, k, v, g-independent O(N)
   activations), exactly like the paper's CUDA autograd function.

All kernels operate on already-feature-mapped q, k (strictly positive);
the public wrappers apply phi(x) = elu(x)+1 when feature_map=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .feature_maps import elu_plus_one

EPS = 1e-6
DEFAULT_CHUNK = 64


# ---------------------------------------------------------------------------
# 1. scan kernel — Algorithm 1, forward
# ---------------------------------------------------------------------------


def _causal_scan_kernel(q_ref, k_ref, v_ref, o_ref):
    """One (batch*head) slice; sequential scan carrying (S, Z).

    CUDA mapping: the (S, Z) carry lives where the paper keeps its running
    per-block accumulator in registers; here it is a fori_loop carry that
    Mosaic would register-allocate, VMEM-resident in the worst case.
    """
    q = q_ref[0]  # (N, D)
    k = k_ref[0]
    v = v_ref[0]
    n, d = q.shape
    m = v.shape[-1]

    def body(i, carry):
        s, z = carry
        ki = k[i]  # (D,)
        vi = v[i]  # (M,)
        qi = q[i]  # (D,)
        s = s + ki[:, None] * vi[None, :]  # S += phi(K_i) V_i^T (eq. 10)
        z = z + ki  # Z += phi(K_i)        (eq. 11)
        num = jnp.dot(qi, s)  # phi(Q_i)^T S_i
        den = jnp.dot(qi, z) + EPS
        o_ref[0, i, :] = num / den  # eq. 12
        return s, z

    jax.lax.fori_loop(
        0,
        n,
        body,
        (jnp.zeros((d, m), q.dtype), jnp.zeros((d,), q.dtype)),
    )


def _run_bh_kernel(kernel, q, k, v, out_m, interpret=True):
    """Launch `kernel` with one program instance per fused (batch, head)."""
    bh, n, d = q.shape
    m = v.shape[-1]
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, out_m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, out_m), q.dtype),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.jit, static_argnames=("feature_map",))
def causal_linear_attention(q, k, v, feature_map=True):
    """Causal linear attention via the sequential scan kernel (Algorithm 1)."""
    b, h, n, d = q.shape
    m = v.shape[-1]
    if feature_map:
        q = elu_plus_one(q)
        k = elu_plus_one(k)
    out = _run_bh_kernel(
        _causal_scan_kernel,
        q.reshape(b * h, n, d),
        k.reshape(b * h, n, d),
        v.reshape(b * h, n, m),
        m,
    )
    return out.reshape(b, h, n, m)


# ---------------------------------------------------------------------------
# 2. chunked kernel — MXU-shaped forward
# ---------------------------------------------------------------------------


def _make_causal_chunked_kernel(chunk: int):
    def kernel(q_ref, k_ref, v_ref, o_ref):
        q = q_ref[0]  # (N, D)
        k = k_ref[0]
        v = v_ref[0]
        n, d = q.shape
        m = v.shape[-1]
        n_chunks = n // chunk
        mask = jnp.tril(jnp.ones((chunk, chunk), q.dtype))

        # fori_loop (not an unrolled python loop) keeps the lowered HLO a
        # single while-op regardless of N — important because the AOT
        # artifacts go up to N=3072 (CIFAR). Each iteration is two
        # MXU-shaped matmuls (T x D @ D x T, T x T @ T x M) + a state update.
        def body(c, carry):
            s, z = carry
            lo = c * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, lo, chunk, axis=0)
            kc = jax.lax.dynamic_slice_in_dim(k, lo, chunk, axis=0)
            vc = jax.lax.dynamic_slice_in_dim(v, lo, chunk, axis=0)
            intra = jnp.dot(qc, kc.T) * mask  # (T, T), causally masked
            num = jnp.dot(intra, vc) + jnp.dot(qc, s)  # intra + inter chunk
            den = intra.sum(-1) + jnp.dot(qc, z) + EPS
            o_ref[0, pl.dslice(lo, chunk), :] = num / den[:, None]
            s = s + jnp.dot(kc.T, vc)  # (D, M) state flows to next chunk
            z = z + kc.sum(0)
            return s, z

        jax.lax.fori_loop(
            0, n_chunks, body, (jnp.zeros((d, m), q.dtype), jnp.zeros((d,), q.dtype))
        )

    return kernel


@functools.partial(jax.jit, static_argnames=("feature_map", "chunk"))
def causal_linear_attention_chunked(q, k, v, feature_map=True, chunk=DEFAULT_CHUNK):
    """Causal linear attention via the chunked kernel.

    Requires N % chunk == 0 (the L2 model pads sequences to a chunk
    multiple; artifact shapes are always multiples of the chunk).
    """
    b, h, n, d = q.shape
    m = v.shape[-1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not a multiple of chunk {chunk}")
    if feature_map:
        q = elu_plus_one(q)
        k = elu_plus_one(k)
    out = _run_bh_kernel(
        _make_causal_chunked_kernel(chunk),
        q.reshape(b * h, n, d),
        k.reshape(b * h, n, d),
        v.reshape(b * h, n, m),
        m,
    )
    return out.reshape(b, h, n, m)


# ---------------------------------------------------------------------------
# 3. constant-memory custom-vjp (paper section 3.3.1, eqs 13-15)
# ---------------------------------------------------------------------------


def _make_backward_kernel(chunk: int):
    """Pallas kernel computing (dq, dk, dv) for the *mapped* q, k.

    Inputs per (b,h) slice: q, k, v, g (upstream grad of the output) and
    the saved denominators. Two passes, both constant-memory:
      forward pass  — recompute S_i, Z_i, accumulate dq (eq. 13 + den term)
      backward pass — accumulate T_i = sum_{j>=i} q_j Gn_j^T and
                      u_i = sum_{j>=i} h_j q_j for dk (eq. 14), dv (eq. 15)
    where Gn_i = g_i / den_i (numerator grad) and
          h_i = -(g_i . out_i) / den_i (denominator grad).
    Chunked like the forward for compute density.
    """

    def kernel(q_ref, k_ref, v_ref, g_ref, den_ref, out_ref, dq_ref, dk_ref, dv_ref):
        q = q_ref[0]  # (N, D)
        k = k_ref[0]
        v = v_ref[0]  # (N, M)
        g = g_ref[0]  # (N, M)
        den = den_ref[0]  # (N,)
        out = out_ref[0]  # (N, M) forward output (saved)
        n, d = q.shape
        m = v.shape[-1]
        n_chunks = n // chunk
        mask = jnp.tril(jnp.ones((chunk, chunk), q.dtype))

        gn = g / den[:, None]  # numerator grads Gn_i
        hh = -jnp.sum(g * out, axis=-1) / den  # denominator grads h_i

        # ---- forward sweep: dq ----
        def fwd_body(c, carry):
            s, z = carry
            lo = c * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, lo, chunk, axis=0)
            kc = jax.lax.dynamic_slice_in_dim(k, lo, chunk, axis=0)
            vc = jax.lax.dynamic_slice_in_dim(v, lo, chunk, axis=0)
            gc = jax.lax.dynamic_slice_in_dim(gn, lo, chunk, axis=0)
            hc = jax.lax.dynamic_slice_in_dim(hh, lo, chunk, axis=0)
            # eq. 13 intra-chunk: dq_i += sum_{j<=i, same chunk} (Gn_i.V_j) K_j
            gv = jnp.dot(gc, vc.T) * mask  # (T, T): Gn_i . V_j masked
            dqc = jnp.dot(gv, kc)  # (T, D)
            dqc = dqc + jnp.dot(gc, s.T)  # inter-chunk via carried S
            # denominator: dq_i += h_i * Z_i (cumulative inside chunk + carry)
            kcum = jnp.cumsum(kc, axis=0)  # Z within chunk
            dqc = dqc + hc[:, None] * (kcum + z[None, :])
            dq_ref[0, pl.dslice(lo, chunk), :] = dqc
            return s + jnp.dot(kc.T, vc), z + kc.sum(0)

        jax.lax.fori_loop(
            0,
            n_chunks,
            fwd_body,
            (jnp.zeros((d, m), q.dtype), jnp.zeros((d,), q.dtype)),
        )

        # ---- backward sweep: dk, dv ----
        def bwd_body(cc, carry):
            t, u = carry  # T = sum_{j>=i} q_j Gn_j^T ; u = sum_{j>=i} h_j q_j
            c = n_chunks - 1 - cc
            lo = c * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, lo, chunk, axis=0)
            kc = jax.lax.dynamic_slice_in_dim(k, lo, chunk, axis=0)
            vc = jax.lax.dynamic_slice_in_dim(v, lo, chunk, axis=0)
            gc = jax.lax.dynamic_slice_in_dim(gn, lo, chunk, axis=0)
            hc = jax.lax.dynamic_slice_in_dim(hh, lo, chunk, axis=0)
            # intra-chunk pairs (j >= i) use the upper-triangular mask.T
            qg = jnp.dot(kc, qc.T) * mask.T  # (T, T): K_i . Q_j for j >= i
            # dv_i = T_i^T phi(K_i) (eq. 15): intra + carried T
            dvc = jnp.dot(qg, gc) + jnp.dot(kc, t)  # (T, M)
            # dk_i = T_i V_i (eq. 14): intra sum_{j>=i} (Gn_j . V_i) q_j + carry
            gv2 = jnp.dot(vc, gc.T) * mask.T  # (T, T): V_i . Gn_j for j >= i
            dkc = jnp.dot(gv2, qc) + jnp.dot(vc, t.T)  # (T, D)
            # denominator: dk_i += sum_{j>=i} h_j q_j (suffix cumsum + carry)
            hq = hc[:, None] * qc  # (T, D)
            hq_rev = jnp.cumsum(hq[::-1], axis=0)[::-1]  # suffix sums in chunk
            dkc = dkc + hq_rev + u[None, :]
            dk_ref[0, pl.dslice(lo, chunk), :] = dkc
            dv_ref[0, pl.dslice(lo, chunk), :] = dvc
            return t + jnp.dot(qc.T, gc), u + hq.sum(0)

        jax.lax.fori_loop(
            0,
            n_chunks,
            bwd_body,
            (jnp.zeros((d, m), q.dtype), jnp.zeros((d,), q.dtype)),
        )

    return kernel


def _cm_forward_impl(qm, km, v, chunk):
    """Chunked forward returning (out, den) — den saved for the backward."""
    bh, n, d = qm.shape
    m = v.shape[-1]

    def kernel(q_ref, k_ref, v_ref, o_ref, den_ref):
        q = q_ref[0]
        k = k_ref[0]
        vv = v_ref[0]
        n_chunks = n // chunk
        mask = jnp.tril(jnp.ones((chunk, chunk), q.dtype))

        def body(c, carry):
            s, z = carry
            lo = c * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, lo, chunk, axis=0)
            kc = jax.lax.dynamic_slice_in_dim(k, lo, chunk, axis=0)
            vc = jax.lax.dynamic_slice_in_dim(vv, lo, chunk, axis=0)
            intra = jnp.dot(qc, kc.T) * mask
            num = jnp.dot(intra, vc) + jnp.dot(qc, s)
            den = intra.sum(-1) + jnp.dot(qc, z) + EPS
            o_ref[0, pl.dslice(lo, chunk), :] = num / den[:, None]
            den_ref[0, pl.dslice(lo, chunk)] = den
            return s + jnp.dot(kc.T, vc), z + kc.sum(0)

        jax.lax.fori_loop(
            0, n_chunks, body, (jnp.zeros((d, m), q.dtype), jnp.zeros((d,), q.dtype))
        )

    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, m), qm.dtype),
            jax.ShapeDtypeStruct((bh, n), qm.dtype),
        ],
        interpret=True,
    )(qm, km, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _causal_cm(qm, km, v, chunk):
    out, _ = _cm_forward_impl(qm, km, v, chunk)
    return out


def _causal_cm_fwd(qm, km, v, chunk):
    out, den = _cm_forward_impl(qm, km, v, chunk)
    # Constant-memory residuals: O(N (D+M)) inputs + O(N) den + O(N M) out —
    # crucially NOT the O(N D M) stack of S_i a naive autograd would keep.
    return out, (qm, km, v, den, out)


def _causal_cm_bwd(chunk, res, g):
    qm, km, v, den, out = res
    bh, n, d = qm.shape
    m = v.shape[-1]
    dq, dk, dv = pl.pallas_call(
        _make_backward_kernel(chunk),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), qm.dtype),
            jax.ShapeDtypeStruct((bh, n, d), qm.dtype),
            jax.ShapeDtypeStruct((bh, n, m), qm.dtype),
        ],
        interpret=True,
    )(qm, km, v, g, den, out)
    return dq, dk, dv


_causal_cm.defvjp(_causal_cm_fwd, _causal_cm_bwd)


@functools.partial(jax.jit, static_argnames=("feature_map", "chunk"))
def causal_linear_attention_cm(q, k, v, feature_map=True, chunk=DEFAULT_CHUNK):
    """Causal linear attention with the constant-memory custom gradient.

    This is the production training kernel: forward == the chunked kernel,
    backward implements eqs 13-15 (plus the denominator terms handled by
    the paper's autograd) without storing per-step states.
    """
    b, h, n, d = q.shape
    m = v.shape[-1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not a multiple of chunk {chunk}")
    if feature_map:
        q = elu_plus_one(q)
        k = elu_plus_one(k)
    out = _causal_cm(
        q.reshape(b * h, n, d), k.reshape(b * h, n, d), v.reshape(b * h, n, m), chunk
    )
    return out.reshape(b, h, n, m)
