"""Pure-jnp reference oracles for every attention kernel in this repo.

These implementations are deliberately naive and O(N^2) where applicable:
they exist to be *obviously correct*, and the Pallas kernels are tested
against them with assert_allclose (python/tests/test_kernel.py).

Conventions (shared with the Pallas kernels):
  q, k : f32[B, H, N, D]   already feature-mapped for the linear variants
  v    : f32[B, H, N, M]
  out  : f32[B, H, N, M]
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-6


def linear_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Non-causal linearized attention, eq. 4/5 of the paper.

    Computed the *slow* way — materialize the N x N similarity matrix —
    so that associativity-based fast paths can be checked against it.
    """
    sim = jnp.einsum("bhnd,bhmd->bhnm", q, k)  # phi(Q) phi(K)^T
    num = jnp.einsum("bhnm,bhme->bhne", sim, v)
    den = sim.sum(-1, keepdims=True)
    return num / (den + EPS)


def linear_attention_fast(q, k, v):
    """Non-causal linearized attention via associativity (eq. 6): O(N)."""
    kv = jnp.einsum("bhnd,bhne->bhde", k, v)  # phi(K)^T V
    z = k.sum(axis=2)  # sum_j phi(K_j)
    num = jnp.einsum("bhnd,bhde->bhne", q, kv)
    den = jnp.einsum("bhnd,bhd->bhn", q, z)[..., None]
    return num / (den + EPS)


def causal_linear_attention(q, k, v):
    """Causal linearized attention, eq. 9: masked quadratic form."""
    n = q.shape[2]
    sim = jnp.einsum("bhnd,bhmd->bhnm", q, k)
    mask = jnp.tril(jnp.ones((n, n), dtype=sim.dtype))
    sim = sim * mask
    num = jnp.einsum("bhnm,bhme->bhne", sim, v)
    den = sim.sum(-1, keepdims=True)
    return num / (den + EPS)


def causal_numerator(q, k, v):
    """Numerator-only causal linear attention (Algorithm 1 'forward').

    Vbar_i = phi(Q_i)^T S_i with S_i = sum_{j<=i} phi(K_j) V_j^T.
    Used for gradient checks of the custom-vjp kernel.
    """
    n = q.shape[2]
    sim = jnp.einsum("bhnd,bhmd->bhnm", q, k)
    mask = jnp.tril(jnp.ones((n, n), dtype=sim.dtype))
    return jnp.einsum("bhnm,bhme->bhne", sim * mask, v)


def softmax_attention(q, k, v, causal: bool = False):
    """Standard softmax attention (eq. 2), with optional causal mask."""
    d = q.shape[-1]
    logits = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        n = q.shape[2]
        neg = jnp.finfo(logits.dtype).min
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        logits = jnp.where(mask, logits, neg)
    weights = jnp.exp(logits - logits.max(-1, keepdims=True))
    weights = weights / weights.sum(-1, keepdims=True)
    return jnp.einsum("bhnm,bhme->bhne", weights, v)


def recurrent_linear_attention(q, k, v):
    """Eqs. 16-20: the RNN view, a python loop over timesteps.

    Slowest but most literal transcription of section 3.4 — the oracle for
    the rust LinearAttnState cell and for the scan/chunked Pallas kernels.
    """
    b, h, n, d = q.shape
    m = v.shape[-1]
    s = jnp.zeros((b, h, d, m), dtype=q.dtype)
    z = jnp.zeros((b, h, d), dtype=q.dtype)
    outs = []
    for i in range(n):
        ki = k[:, :, i, :]
        vi = v[:, :, i, :]
        qi = q[:, :, i, :]
        s = s + ki[..., :, None] * vi[..., None, :]  # phi(K_i) V_i^T
        z = z + ki
        num = jnp.einsum("bhd,bhdm->bhm", qi, s)
        den = jnp.einsum("bhd,bhd->bh", qi, z)[..., None]
        outs.append(num / (den + EPS))
    return jnp.stack(outs, axis=2)
