"""L1: Pallas kernels for linearized attention + baselines, and jnp oracles.

Public surface:
  linear_attention                — non-causal, O(N) (eq. 6)
  causal_linear_attention         — Algorithm-1 scan kernel
  causal_linear_attention_chunked — MXU-shaped chunked kernel
  causal_linear_attention_cm      — chunked + constant-memory custom vjp
  softmax_attention               — O(N^2) baseline kernel
  feature_maps                    — phi(x) = elu(x)+1 and ablations
  ref                             — pure-jnp oracles (tests only)
"""

from . import feature_maps, ref
from .causal_linear_attention import (
    causal_linear_attention,
    causal_linear_attention_chunked,
    causal_linear_attention_cm,
)
from .linear_attention import linear_attention
from .softmax_attention import softmax_attention

__all__ = [
    "feature_maps",
    "ref",
    "linear_attention",
    "causal_linear_attention",
    "causal_linear_attention_chunked",
    "causal_linear_attention_cm",
    "softmax_attention",
]
