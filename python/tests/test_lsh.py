"""Tests for the jax LSH attention baseline (Reformer structure)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import lsh_attention as L
from compile.kernels import ref


def setup(seed=0, b=1, h=2, n=64, d=8, m=8, rounds=2, buckets=8):
    rng = np.random.default_rng(seed)
    qk = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, n, m)), jnp.float32)
    rot = L.make_rotations(jax.random.PRNGKey(seed), rounds, d, buckets)
    return qk, v, rot


class TestBucketing:
    def test_bucket_range(self):
        qk, _, rot = setup(buckets=8)
        b = L._bucket_ids(qk, rot[0])
        bn = np.asarray(b)
        assert bn.min() >= 0 and bn.max() < 8

    def test_identical_vectors_same_bucket(self):
        qk, _, rot = setup()
        x = qk.at[:, :, 1].set(qk[:, :, 0])
        b = np.asarray(L._bucket_ids(x, rot[0]))
        assert (b[..., 0] == b[..., 1]).all()

    def test_opposite_vectors_different_bucket(self):
        # angular LSH maps x and -x to complementary buckets
        qk, _, rot = setup()
        x = qk.at[:, :, 1].set(-qk[:, :, 0])
        b = np.asarray(L._bucket_ids(x, rot[0]))
        assert (b[..., 0] != b[..., 1]).all()

    def test_chunk_mask_shapes_and_lookback(self):
        buckets = jnp.asarray(np.random.default_rng(0).integers(0, 4, (1, 1, 32)))
        m = np.asarray(L._chunk_mask(buckets, chunk=8))
        assert m.shape == (1, 1, 32, 32)
        # every row has at least its own chunk (8) and at most 2 chunks (16)
        rowsums = m.sum(-1)
        assert rowsums.min() >= 8 and rowsums.max() <= 16


class TestLshAttention:
    def test_output_shape_finite(self):
        qk, v, rot = setup()
        out = L.lsh_attention(qk, v, rot, chunk=16)
        assert out.shape == v.shape
        assert bool(jnp.isfinite(out).all())

    def test_causality_in_values(self):
        # Causal masking: a future position's VALUE can never leak into the
        # past. (Its key *can* reshuffle bucket boundaries — an inherent
        # Reformer property — so we perturb v only, keeping hashes fixed.)
        qk, v, rot = setup(seed=1)
        base = np.asarray(L.lsh_attention(qk, v, rot, chunk=16, causal=True))
        v2 = v.at[0, :, -1].add(10.0)
        pert = np.asarray(L.lsh_attention(qk, v2, rot, chunk=16, causal=True))
        np.testing.assert_allclose(base[0, :, :-1], pert[0, :, :-1], rtol=1e-4, atol=1e-4)

    def test_single_chunk_equals_full_softmax_structure(self):
        # with chunk >= N and one round, every position sees all others:
        # result must be close to full softmax attention with shared qk
        # (up to the normalized-key and diagonal-handling differences, so we
        # check correlation rather than equality on the off-diagonal mass).
        qk, v, rot = setup(seed=2, n=32, rounds=1)
        out = L.lsh_attention(qk, v, rot, chunk=32, causal=True)
        # same candidate set as full causal attention; sanity: convex-ish hull
        vn = np.asarray(v)
        assert np.asarray(out).max() <= vn.max() + 1e-3
        assert np.asarray(out).min() >= vn.min() - 1e-3

    def test_rounds_reduce_to_single_when_identical(self):
        qk, v, rot = setup(seed=3, rounds=1)
        rot2 = jnp.concatenate([rot, rot], axis=0)  # two identical rounds
        a = L.lsh_attention(qk, v, rot, chunk=16)
        b = L.lsh_attention(qk, v, rot2, chunk=16)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_differentiable(self):
        qk, v, rot = setup(seed=4)

        def f(qk, v):
            return (L.lsh_attention(qk, v, rot, chunk=16) ** 2).sum()

        gq, gv = jax.grad(f, argnums=(0, 1))(qk, v)
        assert bool(jnp.isfinite(gq).all()) and bool(jnp.isfinite(gv).all())
        assert float(jnp.abs(gv).max()) > 0
