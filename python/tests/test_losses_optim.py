"""Tests for losses.py (incl. CTC vs brute force), optimizers.py, ltw.py."""

import itertools
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import losses
from compile.ltw import read_ltw, write_ltw
from compile.optimizers import (
    OptState,
    adam_update,
    clip_by_global_norm,
    init_opt_state,
    radam_update,
)


# ---------------------------------------------------------------------------
# cross entropy / bits per dim
# ---------------------------------------------------------------------------


class TestCrossEntropy:
    def test_uniform_logits(self):
        v = 8
        logits = jnp.zeros((2, 5, v))
        targets = jnp.zeros((2, 5), jnp.int32)
        np.testing.assert_allclose(
            losses.cross_entropy(logits, targets), np.log(v), rtol=1e-5
        )

    def test_perfect_prediction(self):
        logits = jnp.full((1, 3, 4), -100.0)
        targets = jnp.asarray([[0, 1, 2]], jnp.int32)
        logits = logits.at[0, jnp.arange(3), targets[0]].set(100.0)
        assert float(losses.cross_entropy(logits, targets)) < 1e-4

    def test_mask_excludes_positions(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(size=(1, 4, 5)), jnp.float32)
        targets = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
        mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
        # corrupting masked positions must not change the loss
        logits2 = logits.at[0, 2:].add(7.0)
        a = losses.cross_entropy(logits, targets, mask)
        b = losses.cross_entropy(logits2, targets, mask)
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_bits_per_dim_is_ce_over_log2(self):
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.normal(size=(2, 6, 16)), jnp.float32)
        targets = jnp.asarray(rng.integers(0, 16, (2, 6)), jnp.int32)
        np.testing.assert_allclose(
            losses.bits_per_dim(logits, targets),
            losses.cross_entropy(logits, targets) / np.log(2.0),
            rtol=1e-6,
        )


# ---------------------------------------------------------------------------
# CTC vs brute-force enumeration
# ---------------------------------------------------------------------------


def brute_force_ctc(log_probs, labels, blank=0):
    """Sum path probabilities over all alignments that collapse to `labels`."""
    t, v = log_probs.shape

    def collapse(path):
        out = []
        prev = None
        for s in path:
            if s != prev and s != blank:
                out.append(s)
            prev = s
        return tuple(out)

    total = -np.inf
    for path in itertools.product(range(v), repeat=t):
        if collapse(path) == tuple(labels):
            lp = sum(log_probs[i, s] for i, s in enumerate(path))
            total = np.logaddexp(total, lp)
    return -total


class TestCtc:
    @pytest.mark.parametrize("labels", [[1], [1, 2], [2, 2], [1, 2, 1]])
    def test_matches_brute_force(self, labels):
        rng = np.random.default_rng(42)
        t, v = 4, 3
        logits = rng.normal(size=(t, v)).astype(np.float32)
        logp = np.asarray(jax.nn.log_softmax(jnp.asarray(logits), axis=-1))
        want = brute_force_ctc(logp, labels)
        s_max = 6
        lab = np.zeros((1, s_max), np.int32)
        lab[0, : len(labels)] = labels
        got = losses.ctc_loss(
            jnp.asarray(logp)[None],
            jnp.asarray([t], jnp.int32),
            jnp.asarray(lab),
            jnp.asarray([len(labels)], jnp.int32),
        )
        np.testing.assert_allclose(float(got), want, rtol=1e-4)

    def test_impossible_label_longer_than_frames(self):
        # |labels| > T: probability 0 => loss explodes toward +inf
        logp = jnp.log(jnp.full((1, 2, 3), 1.0 / 3.0))
        loss = losses.ctc_loss(
            logp,
            jnp.asarray([2], jnp.int32),
            jnp.asarray([[1, 2, 1, 0]], jnp.int32),
            jnp.asarray([3], jnp.int32),
        )
        assert float(loss) > 1e4

    def test_frame_lengths_respected(self):
        # frames past frame_len must not affect the loss
        rng = np.random.default_rng(7)
        logp = jax.nn.log_softmax(jnp.asarray(rng.normal(size=(1, 6, 4)), jnp.float32))
        lab = jnp.asarray([[1, 2, 0, 0]], jnp.int32)
        ll = jnp.asarray([2], jnp.int32)
        fl = jnp.asarray([4], jnp.int32)
        a = losses.ctc_loss(logp, fl, lab, ll)
        logp2 = logp.at[0, 4:].add(3.0)  # corrupt padding frames
        logp2 = jax.nn.log_softmax(logp2, axis=-1)
        b = losses.ctc_loss(logp2, fl, lab, ll)
        np.testing.assert_allclose(float(a), float(b), rtol=1e-5)

    def test_batched_matches_individual(self):
        rng = np.random.default_rng(8)
        logp = jax.nn.log_softmax(jnp.asarray(rng.normal(size=(2, 5, 4)), jnp.float32))
        labs = jnp.asarray([[1, 0, 0], [2, 3, 0]], jnp.int32)
        lls = jnp.asarray([1, 2], jnp.int32)
        fls = jnp.asarray([5, 4], jnp.int32)
        both = losses.ctc_loss(logp, fls, labs, lls)
        a = losses.ctc_loss(logp[:1], fls[:1], labs[:1], lls[:1])
        b = losses.ctc_loss(logp[1:], fls[1:], labs[1:], lls[1:])
        np.testing.assert_allclose(float(both), (float(a) + float(b)) / 2, rtol=1e-5)

    def test_gradient_is_finite(self):
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.normal(size=(2, 8, 5)), jnp.float32)

        def f(x):
            logp = jax.nn.log_softmax(x, axis=-1)
            return losses.ctc_loss(
                logp,
                jnp.asarray([8, 6], jnp.int32),
                jnp.asarray([[1, 2, 0], [3, 0, 0]], jnp.int32),
                jnp.asarray([2, 1], jnp.int32),
            )

        g = jax.grad(f)(x)
        assert bool(jnp.isfinite(g).all())


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def quadratic_params(seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=s), jnp.float32) for s in [(4, 3), (5,), ()]]


class TestOptimizers:
    @pytest.mark.parametrize("update", [radam_update, adam_update], ids=["radam", "adam"])
    def test_converges_on_quadratic(self, update):
        params = quadratic_params()
        st = init_opt_state(params)

        def loss(ps):
            return sum(jnp.sum(p * p) for p in ps)

        for _ in range(300):
            grads = jax.grad(loss)(params)
            params, st = update(params, grads, st, jnp.float32(0.05))
        assert float(loss(params)) < 1e-2

    def test_radam_early_steps_are_sgd_like(self):
        # for the first few steps rho_t <= 5: the update must not divide by
        # sqrt(v) (variance not yet rectified) — check that two different
        # gradient magnitudes produce proportionally different steps.
        p = [jnp.ones((1,), jnp.float32)]
        st = init_opt_state(p)
        p1, _ = radam_update(p, [jnp.asarray([1.0])], st, jnp.float32(0.1))
        p2, _ = radam_update(p, [jnp.asarray([2.0])], st, jnp.float32(0.1))
        d1 = float((p[0] - p1[0])[0])
        d2 = float((p[0] - p2[0])[0])
        np.testing.assert_allclose(d2 / d1, 2.0, rtol=1e-4)  # adam would give 1.0

    def test_step_counter_increments(self):
        p = quadratic_params(1)
        st = init_opt_state(p)
        g = jax.grad(lambda ps: sum(jnp.sum(x * x) for x in ps))(p)
        _, st = radam_update(p, g, st, jnp.float32(0.01))
        assert float(st.step) == 1.0
        _, st = radam_update(p, g, st, jnp.float32(0.01))
        assert float(st.step) == 2.0

    def test_clip_by_global_norm(self):
        g = [jnp.asarray([3.0, 4.0])]  # norm 5
        clipped = clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(clipped[0])), 1.0, rtol=1e-5
        )
        # under the limit: untouched
        g2 = clip_by_global_norm(g, 10.0)
        np.testing.assert_allclose(g2[0], g[0], rtol=1e-6)


# ---------------------------------------------------------------------------
# LTW1 round trip
# ---------------------------------------------------------------------------


class TestLtw:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        tensors = [
            ("a.weight", rng.normal(size=(3, 4)).astype(np.float32)),
            ("b.bias", rng.normal(size=(7,)).astype(np.float32)),
            ("c.scalar", np.asarray(2.5, np.float32)),
            ("d.ints", rng.integers(0, 100, (2, 2)).astype(np.int32)),
        ]
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.ltw")
            write_ltw(p, tensors)
            back = read_ltw(p)
        assert [n for n, _ in back] == [n for n, _ in tensors]
        for (_, a), (_, b) in zip(tensors, back):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(a, b)

    def test_rejects_bad_magic(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "bad.ltw")
            with open(p, "wb") as f:
                f.write(b"NOPE\x00\x00\x00\x00")
            with pytest.raises(ValueError):
                read_ltw(p)

    def test_rejects_unsupported_dtype(self):
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(ValueError):
                write_ltw(os.path.join(d, "x.ltw"), [("x", np.zeros(3, np.float64))])

    @settings(max_examples=20, deadline=None)
    @given(
        st.lists(
            st.tuples(
                st.text(alphabet="abcdef.0123", min_size=1, max_size=20),
                st.lists(st.integers(1, 5), min_size=0, max_size=3),
            ),
            min_size=1,
            max_size=5,
        ),
        st.integers(0, 2**31 - 1),
    )
    def test_roundtrip_hypothesis(self, specs, seed):
        rng = np.random.default_rng(seed)
        tensors = [
            (f"{i}.{name}", rng.normal(size=tuple(shape)).astype(np.float32))
            for i, (name, shape) in enumerate(specs)
        ]
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.ltw")
            write_ltw(p, tensors)
            back = read_ltw(p)
        for (n1, a), (n2, b) in zip(tensors, back):
            assert n1 == n2
            np.testing.assert_array_equal(a, b)
