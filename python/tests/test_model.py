"""L2 model tests: shapes, parity of the three inference formulations.

The key reproduction invariants live here:
  * decode_step (the RNN view, eqs 16-20) step-by-step equals the parallel
    forward() — i.e. "Transformers are RNNs" holds numerically.
  * prefill() hands decode_step a state it can continue from seamlessly.
  * decode_step_kv (stateful-softmax) equals the softmax forward().
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.model import ModelConfig

CFG_LIN = ModelConfig(vocab=12, d_model=32, n_heads=2, n_layers=2, max_len=32, d_ff=64, chunk=16, attention="linear")
CFG_SM = ModelConfig(vocab=12, d_model=32, n_heads=2, n_layers=2, max_len=32, d_ff=64, chunk=16, attention="softmax")
CFG_LSH = ModelConfig(
    vocab=12, d_model=32, n_heads=2, n_layers=2, max_len=32, d_ff=64,
    attention="lsh", lsh_rounds=2, lsh_buckets=8, lsh_chunk=8,
)


def tokens(cfg, b=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, cfg.max_len)), jnp.int32)


class TestForwardShapes:
    @pytest.mark.parametrize("cfg", [CFG_LIN, CFG_SM, CFG_LSH], ids=["linear", "softmax", "lsh"])
    def test_forward_shape(self, cfg):
        params = M.init_params(cfg, 0)
        t = tokens(cfg)
        logits = M.forward(cfg, params, t)
        assert logits.shape == (2, cfg.max_len, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_param_names_cover_init(self):
        for cfg in (CFG_LIN, CFG_LSH):
            params = M.init_params(cfg, 0)
            assert sorted(M.param_names(cfg)) == sorted(params)

    def test_params_roundtrip(self):
        params = M.init_params(CFG_LIN, 3)
        lst = M.params_to_list(CFG_LIN, params)
        back = M.params_from_list(CFG_LIN, lst)
        for n in params:
            np.testing.assert_array_equal(params[n], back[n])


class TestTransformersAreRnns:
    """Section 3.4: the causal transformer == an RNN, numerically."""

    def test_decode_matches_forward(self):
        params = M.init_params(CFG_LIN, 1)
        t = tokens(CFG_LIN, b=2, seed=1)
        full = M.forward(CFG_LIN, params, t)  # [B, N, V]
        s, z = M.init_decode_state(CFG_LIN, 2)
        for i in range(CFG_LIN.max_len):
            logits, s, z = M.decode_step(CFG_LIN, params, t[:, i], jnp.full((2,), i, jnp.int32), s, z)
            np.testing.assert_allclose(
                logits, full[:, i], rtol=2e-3, atol=2e-3,
                err_msg=f"RNN view diverged from parallel view at position {i}",
            )

    def test_prefill_matches_stepwise_state(self):
        params = M.init_params(CFG_LIN, 2)
        t = tokens(CFG_LIN, b=1, seed=2)
        logits_pre, s_pre, z_pre = M.prefill(CFG_LIN, params, t)
        s, z = M.init_decode_state(CFG_LIN, 1)
        for i in range(CFG_LIN.max_len):
            logits, s, z = M.decode_step(CFG_LIN, params, t[:, i], jnp.full((1,), i, jnp.int32), s, z)
        np.testing.assert_allclose(s_pre, s, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(z_pre, z, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(logits_pre[:, -1], logits, rtol=2e-3, atol=2e-3)

    def test_decode_cost_state_is_constant_size(self):
        s, z = M.init_decode_state(CFG_LIN, 4)
        # L x B x H x D x D and L x B x H x D — independent of sequence length
        assert s.shape == (2, 4, 2, 16, 16)
        assert z.shape == (2, 4, 2, 16)


class TestStatefulSoftmax:
    def test_kv_decode_matches_forward(self):
        params = M.init_params(CFG_SM, 1)
        t = tokens(CFG_SM, b=2, seed=3)
        full = M.forward(CFG_SM, params, t)
        kc, vc = M.init_kv_cache(CFG_SM, 2)
        for i in range(CFG_SM.max_len):
            logits, kc, vc = M.decode_step_kv(CFG_SM, params, t[:, i], jnp.full((2,), i, jnp.int32), kc, vc)
            np.testing.assert_allclose(
                logits, full[:, i], rtol=2e-3, atol=2e-3,
                err_msg=f"KV-cache decode diverged at position {i}",
            )


class TestEncoder:
    def test_speech_forward_shapes_and_normalization(self):
        from compile import models_speech as S

        cfg = ModelConfig(
            vocab=9, d_model=32, n_heads=2, n_layers=2, max_len=24, d_ff=64,
            attention="linear", causal=False,
        )
        params = S.init_speech_params(cfg, n_mels=13, seed=0)
        feats = jnp.asarray(np.random.default_rng(0).normal(size=(3, 24, 13)), jnp.float32)
        logp = S.speech_forward(cfg, params, feats)
        assert logp.shape == (3, 24, 9)
        # log-softmax rows sum to 1 in prob space
        np.testing.assert_allclose(jnp.exp(logp).sum(-1), 1.0, rtol=1e-4)

    def test_bilstm_shapes(self):
        from compile import models_speech as S

        lcfg = S.LstmConfig(n_mels=13, hidden=16, n_layers=2, vocab=9)
        params = S.init_lstm_params(lcfg, 0)
        feats = jnp.asarray(np.random.default_rng(1).normal(size=(2, 20, 13)), jnp.float32)
        logp = S.lstm_forward(lcfg, params, feats)
        assert logp.shape == (2, 20, 9)
        np.testing.assert_allclose(jnp.exp(logp).sum(-1), 1.0, rtol=1e-4)

    def test_bilstm_uses_future_context(self):
        # bidirectionality: perturbing the last frame must change the first
        from compile import models_speech as S

        lcfg = S.LstmConfig(n_mels=8, hidden=8, n_layers=1, vocab=5)
        params = S.init_lstm_params(lcfg, 0)
        feats = jnp.asarray(np.random.default_rng(2).normal(size=(1, 10, 8)), jnp.float32)
        a = S.lstm_forward(lcfg, params, feats)
        b = S.lstm_forward(lcfg, params, feats.at[0, -1].add(5.0))
        assert np.abs(np.asarray(a - b))[0, 0].max() > 1e-6


class TestLshModel:
    def test_lsh_forward_deterministic_and_finite(self):
        # Token-level strict causality does not hold for LSH (future keys
        # reshuffle bucket boundaries — inherent to Reformer; value-level
        # causality is covered in test_lsh.py). Here: determinism + sanity.
        params = M.init_params(CFG_LSH, 0)
        t = tokens(CFG_LSH, b=1, seed=4)
        a = M.forward(CFG_LSH, params, t)
        b = M.forward(CFG_LSH, params, t)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert bool(jnp.isfinite(a).all())

    def test_lsh_trains(self):
        # one gradient step decreases loss on a fixed batch
        from compile.losses import cross_entropy
        from compile.optimizers import OptState, init_opt_state, radam_update

        params = M.init_params(CFG_LSH, 0)
        names = M.param_names(CFG_LSH)
        plist = M.params_to_list(CFG_LSH, params)
        t = tokens(CFG_LSH, b=4, seed=5)

        def loss_fn(plist):
            pd = dict(zip(names, plist))
            logits = M.forward(CFG_LSH, pd, t[:, :-1])
            return cross_entropy(logits, t[:, 1:])

        st = init_opt_state(plist)
        l0, grads = jax.value_and_grad(loss_fn)(plist)
        for _ in range(5):
            _, grads = jax.value_and_grad(loss_fn)(plist)
            plist, st = radam_update(plist, grads, st, jnp.float32(1e-2))
        l1 = loss_fn(plist)
        assert float(l1) < float(l0)
