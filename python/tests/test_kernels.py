"""L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.

This is the core correctness signal of the compile path — hypothesis sweeps
shapes, pytest parametrizes kernel variants, and the constant-memory custom
vjp is checked against jax.grad of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref
from compile.kernels.feature_maps import elu_plus_one, get_feature_map

RTOL, ATOL = 2e-4, 2e-5


def rand_qkv(seed, b, h, n, d, m, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, n, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, h, n, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, h, n, m)), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# fixed-shape parity checks
# ---------------------------------------------------------------------------


class TestLinearAttention:
    def test_matches_reference(self):
        q, k, v = rand_qkv(0, 2, 3, 64, 16, 24)
        got = K.linear_attention(q, k, v)
        want = ref.linear_attention(elu_plus_one(q), elu_plus_one(k), v)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_fast_reference_matches_slow_reference(self):
        q, k, v = rand_qkv(1, 1, 2, 96, 8, 8)
        qm, km = elu_plus_one(q), elu_plus_one(k)
        np.testing.assert_allclose(
            ref.linear_attention_fast(qm, km, v),
            ref.linear_attention(qm, km, v),
            rtol=RTOL,
            atol=ATOL,
        )

    def test_prefeatured_inputs(self):
        # feature_map=False must consume q,k verbatim
        q, k, v = rand_qkv(2, 1, 1, 32, 8, 8)
        qm, km = elu_plus_one(q), elu_plus_one(k)
        got = K.linear_attention(qm, km, v, feature_map=False)
        want = ref.linear_attention(qm, km, v)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_output_is_convex_combination_scale(self):
        # with positive phi, outputs are weighted averages of V rows:
        # each output must lie within [min, max] of V per channel.
        q, k, v = rand_qkv(3, 1, 1, 48, 8, 4)
        out = np.asarray(K.linear_attention(q, k, v))[0, 0]
        vn = np.asarray(v)[0, 0]
        assert out.min() >= vn.min() - 1e-4
        assert out.max() <= vn.max() + 1e-4


CAUSAL_VARIANTS = [
    ("scan", lambda q, k, v: K.causal_linear_attention(q, k, v)),
    ("chunked", lambda q, k, v: K.causal_linear_attention_chunked(q, k, v, chunk=32)),
    ("cm", lambda q, k, v: K.causal_linear_attention_cm(q, k, v, chunk=32)),
]


class TestCausalLinearAttention:
    @pytest.mark.parametrize("name,fn", CAUSAL_VARIANTS, ids=lambda x: x if isinstance(x, str) else "")
    def test_matches_reference(self, name, fn):
        q, k, v = rand_qkv(4, 2, 2, 64, 16, 16)
        want = ref.causal_linear_attention(elu_plus_one(q), elu_plus_one(k), v)
        np.testing.assert_allclose(fn(q, k, v), want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("name,fn", CAUSAL_VARIANTS, ids=lambda x: x if isinstance(x, str) else "")
    def test_matches_rnn_view(self, name, fn):
        # section 3.4: the causal kernel must equal the explicit RNN loop
        q, k, v = rand_qkv(5, 1, 2, 32, 8, 8)
        want = ref.recurrent_linear_attention(elu_plus_one(q), elu_plus_one(k), v)
        np.testing.assert_allclose(fn(q, k, v), want, rtol=RTOL, atol=ATOL)

    def test_causality(self):
        # perturbing position j must not change outputs at positions < j
        q, k, v = rand_qkv(6, 1, 1, 64, 8, 8)
        base = np.asarray(K.causal_linear_attention(q, k, v))
        j = 40
        k2 = k.at[0, 0, j].add(3.0)
        v2 = v.at[0, 0, j].add(-2.0)
        pert = np.asarray(K.causal_linear_attention(q, k2, v2))
        np.testing.assert_allclose(base[0, 0, :j], pert[0, 0, :j], rtol=1e-6, atol=1e-6)
        assert np.abs(base[0, 0, j:] - pert[0, 0, j:]).max() > 1e-4

    def test_chunk_size_invariance(self):
        q, k, v = rand_qkv(7, 1, 2, 128, 8, 8)
        a = K.causal_linear_attention_chunked(q, k, v, chunk=16)
        b = K.causal_linear_attention_chunked(q, k, v, chunk=64)
        np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)

    def test_rejects_misaligned_chunk(self):
        q, k, v = rand_qkv(8, 1, 1, 48, 8, 8)
        with pytest.raises(ValueError):
            K.causal_linear_attention_chunked(q, k, v, chunk=32)

    def test_first_position_is_v0(self):
        # at i=0 the causal average has a single term: out_0 == v_0
        q, k, v = rand_qkv(9, 1, 1, 16, 8, 8)
        out = np.asarray(K.causal_linear_attention(q, k, v))
        np.testing.assert_allclose(out[0, 0, 0], np.asarray(v)[0, 0, 0], rtol=1e-4, atol=1e-4)


class TestSoftmaxAttention:
    @pytest.mark.parametrize("causal", [False, True])
    def test_matches_reference(self, causal):
        q, k, v = rand_qkv(10, 2, 2, 64, 16, 16)
        got = K.softmax_attention(q, k, v, causal=causal)
        want = ref.softmax_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_rows_sum_preserved(self):
        # attention output of constant V must be that constant
        q, k, _ = rand_qkv(11, 1, 1, 32, 8, 8)
        v = jnp.ones((1, 1, 32, 8), jnp.float32) * 2.5
        out = np.asarray(K.softmax_attention(q, k, v, causal=True))
        np.testing.assert_allclose(out, 2.5, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gradient checks for the constant-memory vjp (paper eqs 13-15)
# ---------------------------------------------------------------------------


class TestConstantMemoryGradient:
    def _grads(self, fn, q, k, v):
        return jax.grad(lambda *a: (fn(*a) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)

    def test_matches_autodiff_of_reference(self):
        q, k, v = rand_qkv(12, 2, 2, 64, 8, 12)
        got = self._grads(
            lambda q, k, v: K.causal_linear_attention_cm(q, k, v, chunk=32), q, k, v
        )
        want = self._grads(
            lambda q, k, v: ref.causal_linear_attention(
                elu_plus_one(q), elu_plus_one(k), v
            ),
            q,
            k,
            v,
        )
        for g1, g2, name in zip(got, want, "qkv"):
            np.testing.assert_allclose(g1, g2, rtol=5e-3, atol=5e-4, err_msg=name)

    def test_gradient_chunk_size_invariance(self):
        # the backward kernel splits its cumulative sums at chunk borders;
        # grads must not depend on where the borders fall. (The scan kernel
        # itself is not reverse-differentiable — in-kernel fori_loop stores —
        # which is exactly why the custom vjp exists.)
        q, k, v = rand_qkv(13, 1, 2, 64, 8, 8)
        g16 = self._grads(
            lambda q, k, v: K.causal_linear_attention_cm(q, k, v, chunk=16), q, k, v
        )
        g64 = self._grads(
            lambda q, k, v: K.causal_linear_attention_cm(q, k, v, chunk=64), q, k, v
        )
        for g1, g2, name in zip(g16, g64, "qkv"):
            np.testing.assert_allclose(g1, g2, rtol=5e-3, atol=5e-4, err_msg=name)

    def test_weighted_cotangent(self):
        # non-trivial upstream gradient, not just sum-of-squares
        q, k, v = rand_qkv(14, 1, 1, 32, 8, 8)
        w = jnp.asarray(np.random.default_rng(14).normal(size=(1, 1, 32, 8)), jnp.float32)
        got = self._grads(
            lambda q, k, v: K.causal_linear_attention_cm(q, k, v, chunk=16) * w, q, k, v
        )
        want = self._grads(
            lambda q, k, v: ref.causal_linear_attention(
                elu_plus_one(q), elu_plus_one(k), v
            )
            * w,
            q,
            k,
            v,
        )
        for g1, g2, name in zip(got, want, "qkv"):
            np.testing.assert_allclose(g1, g2, rtol=5e-3, atol=5e-4, err_msg=name)


# ---------------------------------------------------------------------------
# hypothesis sweeps over shapes (and dtypes where meaningful)
# ---------------------------------------------------------------------------


@st.composite
def qkv_shapes(draw):
    b = draw(st.integers(1, 3))
    h = draw(st.integers(1, 4))
    n_chunks = draw(st.integers(1, 4))
    n = 16 * n_chunks
    d = draw(st.sampled_from([4, 8, 16]))
    m = draw(st.sampled_from([4, 8, 16]))
    seed = draw(st.integers(0, 2**31 - 1))
    return b, h, n, d, m, seed


@settings(max_examples=15, deadline=None)
@given(qkv_shapes())
def test_hypothesis_causal_scan(shape):
    b, h, n, d, m, seed = shape
    q, k, v = rand_qkv(seed, b, h, n, d, m)
    want = ref.causal_linear_attention(elu_plus_one(q), elu_plus_one(k), v)
    np.testing.assert_allclose(
        K.causal_linear_attention(q, k, v), want, rtol=5e-4, atol=5e-5
    )


@settings(max_examples=15, deadline=None)
@given(qkv_shapes())
def test_hypothesis_causal_chunked(shape):
    b, h, n, d, m, seed = shape
    q, k, v = rand_qkv(seed, b, h, n, d, m)
    want = ref.causal_linear_attention(elu_plus_one(q), elu_plus_one(k), v)
    np.testing.assert_allclose(
        K.causal_linear_attention_chunked(q, k, v, chunk=16), want, rtol=5e-4, atol=5e-5
    )


@settings(max_examples=10, deadline=None)
@given(qkv_shapes())
def test_hypothesis_linear_noncausal(shape):
    b, h, n, d, m, seed = shape
    q, k, v = rand_qkv(seed, b, h, n, d, m)
    want = ref.linear_attention(elu_plus_one(q), elu_plus_one(k), v)
    np.testing.assert_allclose(K.linear_attention(q, k, v), want, rtol=5e-4, atol=5e-5)


@settings(max_examples=10, deadline=None)
@given(qkv_shapes(), st.booleans())
def test_hypothesis_softmax(shape, causal):
    b, h, n, d, m, seed = shape
    q, k, v = rand_qkv(seed, b, h, n, d, m)
    want = ref.softmax_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        K.softmax_attention(q, k, v, causal=causal), want, rtol=5e-4, atol=5e-5
    )


# ---------------------------------------------------------------------------
# feature maps
# ---------------------------------------------------------------------------


class TestFeatureMaps:
    def test_elu_plus_one_positive(self):
        # strictly positive in the working range; non-negative everywhere
        # (at x <= -17 float32 rounds exp(x) to 0, so elu(x)+1 == +0.0).
        x = jnp.linspace(-8, 8, 101)
        assert (np.asarray(elu_plus_one(x)) > 0).all()
        xw = jnp.linspace(-50, 50, 101)
        assert (np.asarray(elu_plus_one(xw)) >= 0).all()

    def test_elu_plus_one_gradient_nonzero_for_negative(self):
        g = jax.grad(lambda x: elu_plus_one(x).sum())(jnp.asarray([-3.0, -1.0]))
        assert (np.asarray(g) > 0).all()

    def test_lookup(self):
        assert get_feature_map("elu+1") is elu_plus_one
        with pytest.raises(ValueError):
            get_feature_map("nope")

    def test_identity_region(self):
        # elu(x)+1 == x+1 for x >= 0
        x = jnp.asarray([0.0, 0.5, 3.0])
        np.testing.assert_allclose(elu_plus_one(x), x + 1.0, rtol=1e-6)
