"""AOT pipeline tests: manifest coherence + a real train-step execution.

These run the *lowered* computations through jax (the same HLO the rust
runtime loads), checking that the flat-argument calling convention the
manifest promises actually trains.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, losses, model as M
from compile.model import ModelConfig
from compile.optimizers import OptState, init_opt_state

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def small_cfg(attention="linear"):
    return ModelConfig(
        vocab=11, d_model=32, n_heads=2, n_layers=2, max_len=32, d_ff=64,
        chunk=16, attention=attention,
    )


class TestTrainStepConvention:
    @pytest.mark.parametrize("attention", ["linear", "softmax"])
    def test_flat_train_step_learns(self, attention):
        cfg = small_cfg(attention)
        names = M.param_names(cfg)
        params = M.init_params(cfg, 0)
        plist = M.params_to_list(cfg, params)

        def lm_loss(pd, inputs, targets, mask):
            return losses.cross_entropy(M.forward(cfg, pd, inputs), targets, mask)

        step_fn = jax.jit(aot.make_train_step(names, lm_loss, "radam", None))

        rng = np.random.default_rng(0)
        # learnable toy data: next token = current token (shift task)
        seq = rng.integers(0, cfg.vocab, size=(8, cfg.max_len + 1))
        inputs = jnp.asarray(seq[:, :-1], jnp.int32)
        targets = jnp.asarray(inputs)  # predict the input token itself
        mask = jnp.ones_like(inputs, jnp.float32)

        st = init_opt_state(plist)
        m, v, step = st.m, st.v, st.step
        first = None
        for it in range(60):
            out = step_fn(*plist, *m, *v, step, jnp.float32(1e-2), inputs, targets, mask)
            loss = float(out[0])
            p_count = len(names)
            plist = list(out[1 : 1 + p_count])
            m = list(out[1 + p_count : 1 + 2 * p_count])
            v = list(out[1 + 2 * p_count : 1 + 3 * p_count])
            step = out[-1]
            if first is None:
                first = loss
        assert loss < first * 0.25, f"train step did not learn: {first} -> {loss}"
        assert float(step) == 60.0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_file_exists(self, manifest):
        for name, art in manifest["artifacts"].items():
            path = os.path.join(ARTIFACTS, art["file"])
            assert os.path.exists(path), f"{name}: missing {art['file']}"
            assert os.path.getsize(path) > 1000

    def test_every_model_weight_bundle_exists_and_matches_shapes(self, manifest):
        from compile.ltw import read_ltw

        for key, model in manifest["models"].items():
            path = os.path.join(ARTIFACTS, model["weights"])
            assert os.path.exists(path), f"{key}: missing weights"
            tensors = dict(read_ltw(path))
            assert sorted(tensors) == sorted(model["params"])
            for n, shape in model["param_shapes"].items():
                assert list(tensors[n].shape) == shape, (key, n)

    def test_train_artifact_io_symmetry(self, manifest):
        # outputs of a train step must mirror its param/opt inputs so the
        # rust trainer can feed them straight back in
        for name, art in manifest["artifacts"].items():
            if not name.endswith("_train"):
                continue
            ins = [i["name"] for i in art["inputs"]]
            outs = [o["name"] for o in art["outputs"]]
            state_in = [n for n in ins if n.split(":")[0] in ("param", "opt_m", "opt_v")] + ["opt_step"]
            assert outs[0] == "loss"
            assert outs[1:] == state_in, name
            in_shapes = {i["name"]: i["shape"] for i in art["inputs"]}
            out_shapes = {o["name"]: o["shape"] for o in art["outputs"]}
            for n in state_in:
                assert in_shapes[n] == out_shapes[n], (name, n)

    def test_decode_artifact_state_roundtrip(self, manifest):
        for name, art in manifest["artifacts"].items():
            if "_decode_linear_" not in name:
                continue
            ins = {i["name"]: i["shape"] for i in art["inputs"]}
            outs = {o["name"]: o["shape"] for o in art["outputs"]}
            assert ins["state:s"] == outs["state:s"], name
            assert ins["state:z"] == outs["state:z"], name

    def test_hlo_text_parses_superficially(self, manifest):
        # HLO text round-trip sanity: ENTRY present, parameter count matches
        for name, art in list(manifest["artifacts"].items())[:6]:
            with open(os.path.join(ARTIFACTS, art["file"])) as f:
                text = f.read()
            assert "ENTRY" in text, name
            assert text.count("parameter(") >= len(art["inputs"]), name
